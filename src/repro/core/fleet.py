"""Fleet-scale FL simulation: heterogeneous cohorts of hundreds of clients.

The paper validates MUDP on a 3-node star (2 clients, 1 server) and defers
"a larger Federated learning system" to future work.  This module is that
step: it turns the paper topology into a *scenario engine* —

* :class:`CohortSpec` — a named band of link/compute characteristics
  (``fiber`` / ``lte`` / ``congested-edge`` presets in
  :data:`COHORT_PRESETS`); every per-client quantity is a ``(lo, hi)``
  range.
* :class:`ClientProfile` — one client's concrete draw from its cohort:
  uplink/downlink rate, propagation delay, jitter, loss rate (Bernoulli or
  bursty Gilbert-Elliott), local train time, and aggregation weight.
* :func:`sample_profiles` — the seeded sampler.  It consumes only
  ``random.Random.random()`` (the one generator method with a documented
  cross-version stability guarantee) keyed by integers, so the same
  :class:`FleetConfig` produces **bit-identical** cohorts on every machine
  and Python version.
* :func:`build_fleet` — samples the cohorts and hands them to the
  topology named by ``FleetConfig.topology`` (``repro.core.topology``):
  ``star`` wires the paper's single-server hub (one asymmetric jittered
  lossy :class:`Link` pair per client), ``hier`` adds edge aggregators
  between the clients and the root, ``gossip`` goes serverless over a
  seeded peer graph.  All three return a system with the same
  ``run_round`` / ``run_rounds`` surface, dispatching through whatever
  transport the :class:`FLConfig` names.
* :class:`ConsensusObjective` — a synthetic quadratic objective (each
  client pulls the model toward a private target) whose global loss is
  analytically computable, giving benchmarks a deterministic
  rounds-to-target-loss metric without touching real data.

Partial participation, straggler cutoffs, and the scheduling mode are
*not* implemented here — they are first-class in ``repro.core.rounds`` /
``repro.core.scheduling`` (``participation_fraction``,
``round_deadline_ns``, ``mode="sync"|"async"``, ``buffer_k``);
:class:`FleetConfig` simply carries the knobs.  See ``docs/SCENARIOS.md``
and ``docs/ASYNC.md`` for the full semantics.
"""

from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Any, Callable, Optional

import numpy as np

from repro.core.channel import BernoulliLoss, GilbertElliott, Link, LossModel
from repro.core.rounds import FederatedSystem, FLClient, FLConfig
from repro.core.simulator import Simulator

NS_PER_SEC = 1_000_000_000

Range = tuple[float, float]


# --------------------------------------------------------------------------
# Cohorts
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """A named band of client characteristics; every field is drawn
    per-client, uniformly over its ``(lo, hi)`` range."""

    name: str
    up_rate_bps: Range              # uplink data rate
    down_up_ratio: float = 1.0      # downlink rate = uplink * ratio
    delay_ns: Range = (1_000_000, 5_000_000)
    jitter_frac: float = 0.0        # jitter_ns = jitter_frac * drawn delay
    loss_p: Range = (0.0, 0.0)
    bursty: bool = False            # Gilbert-Elliott instead of Bernoulli
    train_time_ns: Range = (500_000_000, 1_000_000_000)
    weight: Range = (0.5, 2.0)      # |D_k| proxy for weighted FedAvg
    # Async re-entry cadence: how long the device stays unavailable after
    # finishing an upload before it asks for new work (charging, other
    # apps, duty cycling).  Ignored by sync scheduling, where the round
    # barrier sets the cadence.  Drawn from its own RNG stream so adding
    # this field left every pre-existing profile draw bit-identical.
    cadence_ns: Range = (0, 0)


#: The presets the CI scenario matrix exercises. ``fiber`` is the
#: datacenter-adjacent best case, ``lte`` the PeerFL-style mobile mid-band,
#: ``congested-edge`` the FedComm-style constrained edge where protocol
#: rankings flip (slow, jittery, bursty loss -> stragglers and cutoffs).
COHORT_PRESETS: dict[str, CohortSpec] = {
    "fiber": CohortSpec(
        name="fiber",
        up_rate_bps=(200e6, 1000e6),
        down_up_ratio=1.0,
        delay_ns=(1_000_000, 5_000_000),          # 1-5 ms
        jitter_frac=0.1,
        loss_p=(0.0, 0.001),
        bursty=False,
        train_time_ns=(200_000_000, 500_000_000),  # 0.2-0.5 s
        cadence_ns=(50_000_000, 200_000_000),      # 50-200 ms
    ),
    "lte": CohortSpec(
        name="lte",
        up_rate_bps=(5e6, 50e6),
        down_up_ratio=4.0,                         # asymmetric cellular
        delay_ns=(20_000_000, 60_000_000),         # 20-60 ms
        jitter_frac=0.5,
        loss_p=(0.005, 0.03),
        bursty=False,
        train_time_ns=(500_000_000, 2_000_000_000),
        cadence_ns=(200_000_000, 1_000_000_000),   # 0.2-1 s
    ),
    "congested-edge": CohortSpec(
        name="congested-edge",
        up_rate_bps=(0.5e6, 4e6),
        down_up_ratio=2.0,
        delay_ns=(50_000_000, 200_000_000),        # 50-200 ms
        jitter_frac=1.0,
        loss_p=(0.05, 0.15),
        bursty=True,
        train_time_ns=(1_000_000_000, 5_000_000_000),
        cadence_ns=(500_000_000, 3_000_000_000),   # 0.5-3 s
    ),
}

#: Default cohort mix (fractions are normalized; PeerFL-style majority
#: mobile with a constrained tail).
DEFAULT_MIX: tuple[tuple[str, float], ...] = (
    ("fiber", 0.3), ("lte", 0.5), ("congested-edge", 0.2))


# --------------------------------------------------------------------------
# Profiles
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """One client's concrete draw from its cohort."""

    addr: str
    cohort: str
    up_rate_bps: float
    down_rate_bps: float
    delay_ns: int
    jitter_ns: int
    loss_p: float
    bursty: bool
    train_time_ns: int
    weight: float
    seed: int                       # base seed for this client's link RNGs
    cadence_ns: int = 0             # async re-entry gap (sync ignores it)


@dataclasses.dataclass
class FleetConfig:
    """Declarative description of a heterogeneous fleet + round policy."""

    n_clients: int = 100
    cohort_mix: tuple[tuple[str, float], ...] = DEFAULT_MIX
    cohorts: Optional[dict[str, CohortSpec]] = None   # default COHORT_PRESETS
    seed: int = 0
    server_addr: str = "10.0.0.1"
    # Simulator engine: "batched" (the vectorized flight engine — the fleet
    # hot path), "per_packet" (the reference event-per-packet loop; the two
    # are bit-for-bit identical, so that choice is purely a speed knob), or
    # "flow" (the analytic tier — statistically equivalent per the
    # tests/statcheck.py harness, and the only tier that reaches 100k+
    # clients in CI-minutes).
    engine: str = "batched"
    # Round policy, forwarded into FLConfig by build_fleet().
    participation_fraction: float = 1.0
    min_participants: int = 1
    round_deadline_ns: Optional[int] = None
    # Scheduling policy: "sync" (round barrier) or "async" (FedBuff-style
    # buffered aggregation over overlapping sessions; docs/ASYNC.md).
    # Under async, round_deadline_ns becomes the per-session watchdog and
    # buffer_k is the aggregation trigger.
    mode: str = "sync"
    buffer_k: int = 8
    # Batched wire plane (repro.core.wire batch API): decode all arrived
    # uplink payloads in one stacked pass per aggregation and serve a
    # cached broadcast encode when the downlink pipeline is stateless.
    # Byte/bit-identical to the per-client loop, so this is purely a
    # throughput knob; False restores eager per-delivery decode.
    batch_wire: bool = True
    # Wire plane (repro.core.wire): per-direction pipeline specs, forwarded
    # onto the TransportConfig by build_fleet().  None keeps whatever the
    # FLConfig's transport already says (usually the legacy codec).
    uplink: Optional[str] = None        # e.g. "delta|ef|topk(0.01)|int8(1024)"
    downlink: Optional[str] = None      # e.g. "int8(1024)"
    # Topology (repro.core.topology): how the fleet is wired.  "star" is
    # the paper's single server (the default, bit-identical to the
    # pre-topology wiring); "hier" adds `cells` edge aggregators between
    # the clients and the root; "gossip" is serverless peer-to-peer over a
    # seeded ~`neighbors`-regular graph.
    topology: str = "star"
    cells: int = 4                      # hier: number of edge aggregators
    neighbors: int = 4                  # gossip: target peer degree
    edge_cohort: str = "fiber"          # hier: cohort band for edge<->root links
    cell_transport: Optional[str] = None   # hier: client<->edge transport kind
    # Per-hop wire pipeline specs, e.g. for hier:
    #   "client->edge: topk(0.01)|int8(1024); edge->root: delta"
    # Hop names are the topology's (topology_hops(name)); mutually
    # exclusive with the uplink/downlink shorthands above.
    hops: Optional[str] = None
    # What the clients train (repro.core.client_compute model registry):
    # None keeps the caller-supplied train_fn_factory path (build_fleet);
    # "consensus" | "mlp" lets build_fleet_training() construct the model
    # and wire its per-client / batched training into the topology.
    model: Optional[str] = None
    model_args: Optional[dict] = None   # forwarded to the model factory
    # How local training executes (client_compute TrainBackend registry):
    # "python" = today's per-client loop (bit-identical, digest-pinned);
    # "vmap" = one jitted jax.vmap call per pending batch; "shard" = vmap
    # sharded over the local device mesh (falls back to vmap on 1 device).
    train_backend: str = "python"
    # Adaptive transport control plane (repro.core.control): the policy
    # consulted between transactions to renegotiate each client's wire
    # pipeline and FEC geometry from its telemetry.  "static" (default)
    # never renegotiates and is digest-pinned; "adaptive" walks the
    # loss-driven tier ladder.  Forwarded onto FLConfig by the topologies
    # (star and hier; gossip has no server core, so it ignores these).
    control: str = "static"
    control_args: Optional[dict] = None

    def __post_init__(self) -> None:
        # Topology parameters fail at construction, not deep inside
        # build_fleet.  Imported lazily: repro.core.topology imports this
        # module for profiles/links, so a top-level import would be
        # circular (the _scheduler_registry idiom in repro.core.server).
        from repro.core.topology import available_topologies, topology_hops
        from repro.core.transport import validate_transport_kind
        from repro.core.wire import WireError, parse_hop_specs
        if self.n_clients < 1:
            raise ValueError("n_clients must be >= 1")
        if self.topology not in available_topologies():
            raise ValueError(f"unknown topology {self.topology!r}; one of "
                             f"{available_topologies()}")
        if self.topology == "hier":
            if not 1 <= self.cells <= 250:
                raise ValueError("cells must be in [1, 250] (the edge "
                                 "address planes hold 250 aggregators)")
            if self.cells > self.n_clients:
                raise ValueError(f"cells ({self.cells}) cannot exceed "
                                 f"n_clients ({self.n_clients}): an edge "
                                 f"aggregator without a cell serves no one")
            if self.edge_cohort not in self.cohort_specs():
                raise ValueError(f"unknown edge_cohort {self.edge_cohort!r}; "
                                 f"available: {sorted(self.cohort_specs())}")
            if self.cell_transport is not None:
                validate_transport_kind(self.cell_transport)
        if self.topology == "gossip":
            if self.neighbors < 1:
                raise ValueError("gossip degree (neighbors) must be >= 1")
            if self.neighbors >= self.n_clients:
                raise ValueError(f"neighbors ({self.neighbors}) must be < "
                                 f"n_clients ({self.n_clients}): a client "
                                 f"cannot gossip with itself")
        if self.hops is not None:
            if self.uplink is not None or self.downlink is not None:
                raise ValueError("hops= and uplink=/downlink= are two "
                                 "spellings of the same thing; use one")
            try:
                parse_hop_specs(self.hops,
                                known_hops=topology_hops(self.topology))
            except WireError as e:
                raise ValueError(f"invalid hops spec: {e}") from None
        # Model / train-backend wiring (lazy import: client_compute pulls
        # in the model registry, heavy deps load only when asked for).
        from repro.core.client_compute import (available_models,
                                               available_train_backends)
        if self.model is not None and self.model not in available_models():
            raise ValueError(f"unknown model {self.model!r}; one of "
                             f"{available_models()}")
        if self.train_backend not in available_train_backends():
            raise ValueError(
                f"unknown train backend {self.train_backend!r}; one of "
                f"{available_train_backends()}")
        if self.model_args is not None and self.model is None:
            raise ValueError("model_args= without model=: name the model "
                             "the arguments configure")
        from repro.core.control import available_policies
        if self.control not in available_policies():
            raise ValueError(f"unknown control policy {self.control!r}; "
                             f"one of {available_policies()}")
        if self.control_args is not None and self.control == "static":
            raise ValueError("control_args= with control='static': the "
                             "static policy takes no arguments; name the "
                             "policy they configure")

    def cohort_specs(self) -> dict[str, CohortSpec]:
        return self.cohorts if self.cohorts is not None else COHORT_PRESETS

    def cell_of(self, i: int) -> int:
        """Cell membership of client ``i`` under hier: round-robin, so
        every cell sees the same cohort mix in expectation."""
        return i % self.cells


def _client_addr(i: int) -> str:
    # 16-byte address budget (packets.py): "10.1.<hi>.<lo>" stays within it
    # for fleets up to 250 * 250 clients.
    return f"10.1.{i // 250}.{i % 250 + 1}"


def sample_profiles(cfg: FleetConfig) -> list[ClientProfile]:
    """Deterministically draw ``cfg.n_clients`` profiles from the mix.

    Only ``Random.random()`` is consumed, in a fixed order, keyed by
    integers — bit-identical across runs, platforms, and Python versions.
    """
    specs = cfg.cohort_specs()
    mix = list(cfg.cohort_mix)
    if not mix:
        raise ValueError("empty cohort_mix")
    for name, _ in mix:
        if name not in specs:
            raise ValueError(f"unknown cohort {name!r}; available: "
                             f"{sorted(specs)}")
    total_w = sum(max(0.0, w) for _, w in mix)
    if total_w <= 0:
        raise ValueError("cohort_mix weights must sum to > 0")
    cum, acc = [], 0.0
    for name, w in mix:
        acc += max(0.0, w) / total_w
        cum.append((name, acc))

    rng = random.Random(hash((int(cfg.seed), 0xF1EE7)))
    # Cadence draws come from their own stream: appending them to the main
    # stream would have shifted every draw after the first client and
    # silently re-rolled all pre-existing cohorts for a given seed.
    cadence_rng = random.Random(hash((int(cfg.seed), 0xCADE)))

    def u(lo: float, hi: float) -> float:
        return lo + (hi - lo) * rng.random()

    profiles: list[ClientProfile] = []
    for i in range(cfg.n_clients):
        r = rng.random()
        cohort = cum[-1][0]   # fallback guards float round-off on the last edge
        for name, edge in cum:
            if r < edge:
                cohort = name
                break
        spec = specs[cohort]
        up = u(*spec.up_rate_bps)
        delay = int(u(*spec.delay_ns))
        profiles.append(ClientProfile(
            addr=_client_addr(i),
            cohort=cohort,
            up_rate_bps=up,
            down_rate_bps=up * spec.down_up_ratio,
            delay_ns=delay,
            jitter_ns=int(spec.jitter_frac * delay),
            loss_p=u(*spec.loss_p),
            bursty=spec.bursty,
            train_time_ns=int(u(*spec.train_time_ns)),
            weight=u(*spec.weight),
            # Distinct per-client base seed; link RNGs offset from it.
            seed=int(cfg.seed) * 1_000_003 + i * 4,
            cadence_ns=int(spec.cadence_ns[0]
                           + (spec.cadence_ns[1] - spec.cadence_ns[0])
                           * cadence_rng.random()),
        ))
    return profiles


def profiles_digest(profiles: list[ClientProfile]) -> str:
    """Stable content hash of a cohort draw (replay checks, CI artifacts)."""
    h = hashlib.sha256()
    for p in profiles:
        h.update(repr(dataclasses.astuple(p)).encode())
    return h.hexdigest()


def _loss_model(p: ClientProfile, seed: int) -> LossModel:
    if p.bursty:
        # Bad-state loss an order of magnitude above the mean keeps the
        # drawn loss_p as the approximate stationary drop rate.
        return GilbertElliott(p_good_loss=p.loss_p / 4,
                              p_bad_loss=min(1.0, p.loss_p * 10),
                              p_bad=0.075, seed=seed)
    return BernoulliLoss(p=p.loss_p, seed=seed)


def links_for(p: ClientProfile) -> tuple[Link, Link]:
    """(uplink, downlink) for one profile, each with its own seeded loss
    and jitter streams."""
    up = Link(p.up_rate_bps, p.delay_ns, _loss_model(p, p.seed),
              jitter_ns=p.jitter_ns, jitter_seed=p.seed + 2)
    down = Link(p.down_rate_bps, p.delay_ns, _loss_model(p, p.seed + 1),
                jitter_ns=p.jitter_ns, jitter_seed=p.seed + 3)
    return up, down


TrainFnFactory = Callable[[int, ClientProfile], Callable]


def build_fleet(fleet: FleetConfig, global_params: Any,
                train_fn_factory: TrainFnFactory,
                fl_cfg: Optional[FLConfig] = None,
                ) -> tuple[Simulator, Any, list[ClientProfile]]:
    """Sample the cohorts and hand them to ``fleet.topology`` for wiring.

    ``train_fn_factory(i, profile)`` returns the i-th client's train_fn.
    ``fl_cfg`` carries transport/aggregation choices; the fleet's round
    policy (participation, deadline) overrides the corresponding FLConfig
    fields so one FleetConfig means one scenario regardless of transport.

    The returned ``system`` is a :class:`FederatedSystem` under ``star``,
    a ``HierSystem`` under ``hier``, a ``GossipSystem`` under ``gossip`` —
    all with the same ``run_round`` / ``run_rounds`` / ``global_params`` /
    ``history`` / ``on_round_end`` surface (``repro.core.topology``).
    """
    from repro.core.topology import make_topology
    profiles = sample_profiles(fleet)
    topo = make_topology(fleet.topology)
    sim, system = topo.build(fleet, profiles, global_params,
                             train_fn_factory, fl_cfg)
    return sim, system, profiles


@dataclasses.dataclass
class FleetBuild:
    """Everything :func:`build_fleet_training` wired together."""

    sim: Simulator
    system: Any                      # Federated/Hier/GossipSystem
    profiles: list[ClientProfile]
    model: Any                       # the ClientModel instance
    trainer: Optional[Any] = None    # BatchTrainer (None on "python")


def build_fleet_training(fleet: FleetConfig,
                         fl_cfg: Optional[FLConfig] = None) -> FleetBuild:
    """:func:`build_fleet` with the model and train backend wired in.

    The model named by ``fleet.model`` (default ``"consensus"``) supplies
    the global template and every client's training; ``fleet.train_backend
    != "python"`` additionally attaches a
    :class:`~repro.core.client_compute.BatchTrainer` to every training
    site, so each round's local steps run as one vmapped batch.  The
    ``"python"`` default attaches nothing — the topology runs the exact
    historical per-client path the replay digests pin.
    """
    from repro.core.client_compute import (BatchTrainer, attach_trainer,
                                           make_model, make_train_backend)
    model = make_model(fleet.model or "consensus", fleet.n_clients,
                       seed=fleet.seed, **(fleet.model_args or {}))
    sim, system, profiles = build_fleet(
        fleet, model.init_params(),
        lambda i, p: model.train_fn(i, p), fl_cfg)
    trainer = None
    if fleet.train_backend != "python":
        trainer = BatchTrainer(
            model, make_train_backend(fleet.train_backend),
            client_index={p.addr: i for i, p in enumerate(profiles)})
        attach_trainer(system, trainer)
    return FleetBuild(sim=sim, system=system, profiles=profiles,
                      model=model, trainer=trainer)


def cohort_counts(profiles: list[ClientProfile]) -> dict[str, int]:
    out: dict[str, int] = {}
    for p in profiles:
        out[p.cohort] = out.get(p.cohort, 0) + 1
    return out


# --------------------------------------------------------------------------
# Synthetic objective: deterministic rounds-to-target-loss
# --------------------------------------------------------------------------
class ConsensusObjective:
    """Quadratic consensus task: client ``k`` holds a private target
    ``c_k = c + heterogeneity * e_k`` (shared signal + client-specific
    noise) and local training moves the received model toward it,
    ``w' = w + lr * (c_k - w)``.  The reported loss is the distance to the
    consensus optimum ``w* = mean_k c_k``,

        L(w) = ||w - w*||^2 / n_params,

    which FedAvg under full reliable participation contracts geometrically
    (factor ``1 - lr`` per round, plus a small sampling-noise floor under
    partial participation), so "rounds to reach ``frac * L(w_0)``" is an
    analytically grounded convergence metric that lossy transports
    (zero-filled UDP gaps) and straggler cutoffs visibly hurt.
    """

    def __init__(self, n_clients: int, n_params: int, *, seed: int = 0,
                 lr: float = 0.5, heterogeneity: float = 0.1):
        rng = np.random.default_rng(seed)
        common = rng.standard_normal((1, n_params))
        noise = rng.standard_normal((n_clients, n_params))
        self.targets = (common + heterogeneity * noise).astype(np.float32)
        self.optimum = self.targets.mean(axis=0)
        self.lr = float(lr)

    def init_params(self) -> dict[str, np.ndarray]:
        return {"w": np.zeros((self.targets.shape[1],), np.float32)}

    def train_fn(self, i: int, profile: Optional[ClientProfile] = None
                 ) -> Callable:
        target = self.targets[i]

        def fn(params, round_idx, client):
            w = np.asarray(params["w"], np.float32)
            new = {"w": w + self.lr * (target - w)}
            return new, {"local_gap": float(np.mean((w - target) ** 2))}
        return fn

    def loss(self, params) -> float:
        w = np.asarray(params["w"], np.float32)
        return float(np.mean((w - self.optimum) ** 2))
