"""pytree <-> packets.

Algorithm I of the paper: get_weights() -> ConvertToHex -> one packet per
weight. Shipping one packet per scalar weight does not survive contact with a
34B-parameter model, so the production packetizer flattens the parameter
pytree to one float32 vector, encodes it through a **wire pipeline**
(``repro.core.wire`` — a composed stage list; a bare legacy codec is wrapped
into a single-stage headerless pipeline, hex remains available as the
faithful mode), and slices the byte stream into MTU-sized packets with the
paper's (X, Np, A) headers. The receiver side reassembles, verifies
checksums, decodes (self-describing payloads decode from their own
WireHeader), and unflattens against the model template (the FL server knows
the architecture — only weight bytes travel, exactly as in the paper).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import jax
import numpy as np

from repro.core.compression import Codec, RawCodec
from repro.core.packets import HEADER_BYTES, Packet, make_data_packet
from repro.core.wire import (Pipeline, PipelineState, WireError,
                             decode_payload, stage_for_codec)

DEFAULT_MTU = 1500
_IP_UDP_OVERHEAD = 28  # bytes of IP+UDP headers a real datagram would carry


# --------------------------------------------------------------------------
# pytree <-> flat vector
# --------------------------------------------------------------------------
def flatten_to_vector(tree: Any) -> np.ndarray:
    """Deterministic (tree_flatten order) concat of all leaves as float32."""
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return np.zeros(0, dtype=np.float32)
    return np.concatenate(
        [np.asarray(leaf, dtype=np.float32).reshape(-1) for leaf in leaves])


def unflatten_from_vector(vec: np.ndarray, template: Any) -> Any:
    """Rebuild a pytree shaped like ``template`` from a flat float32 vector."""
    leaves, treedef = jax.tree_util.tree_flatten(template)
    out, off = [], 0
    for leaf in leaves:
        leaf = np.asarray(leaf)
        n = leaf.size
        out.append(vec[off:off + n].reshape(leaf.shape).astype(leaf.dtype))
        off += n
    if off != vec.size:
        raise ValueError(f"vector has {vec.size} params, template needs {off}")
    return jax.tree_util.tree_unflatten(treedef, out)


def num_params(tree: Any) -> int:
    return sum(int(np.asarray(l).size) for l in jax.tree_util.tree_leaves(tree))


# --------------------------------------------------------------------------
# bytes <-> packets
# --------------------------------------------------------------------------
def packetize(data: bytes, addr: str, txn: int = 0,
              mtu: int = DEFAULT_MTU) -> list[Packet]:
    """Slice ``data`` into DATA packets with headers (X, Np, A), X=1..Np."""
    payload_max = mtu - _IP_UDP_OVERHEAD
    if payload_max <= 0:
        raise ValueError("mtu too small")
    total = max(1, -(-len(data) // payload_max))
    return [
        make_data_packet(seq=i + 1, total=total, addr=addr, txn=txn,
                         payload=data[i * payload_max:(i + 1) * payload_max])
        for i in range(total)
    ]


def reassemble(packets: dict[int, Packet]) -> bytes:
    """Receiver §IV.B: 'Construct the original file from the packets.'"""
    if not packets:
        return b""
    total = next(iter(packets.values())).total
    missing = [s for s in range(1, total + 1) if s not in packets]
    if missing:
        raise ValueError(f"cannot reassemble, missing sequences {missing}")
    chunks = []
    for seq in range(1, total + 1):
        pkt = packets[seq]
        if not pkt.verify():
            raise ValueError(f"checksum mismatch at sequence {seq}")
        chunks.append(pkt.payload)
    return b"".join(chunks)


# --------------------------------------------------------------------------
# High-level: model <-> packets
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Packetizer:
    """End-to-end path used by FL clients and the server broadcast.

    Construct with a legacy ``codec`` (wrapped into a single-stage
    headerless pipeline — byte-identical to the historical wire format) or
    with an explicit ``pipeline`` (a composed, usually self-describing
    stage list from ``repro.core.wire``).  Stateful pipelines take an
    optional per-endpoint ``PipelineState`` on every call; ``None`` means
    stateless one-shot encoding.
    """

    codec: Optional[Codec] = None
    mtu: int = DEFAULT_MTU
    pipeline: Optional[Pipeline] = None

    def __post_init__(self) -> None:
        if self.pipeline is None:
            if self.codec is None:
                self.codec = RawCodec()
            self.pipeline = Pipeline([stage_for_codec(self.codec)],
                                     self_describing=False)
        elif self.codec is not None:
            raise WireError(
                "pass either codec= (legacy single-stage) or pipeline=, "
                "not both — the codec would be silently ignored")

    def encode_bytes(self, tree: Any,
                     state: Optional[PipelineState] = None) -> bytes:
        return self.pipeline.encode(flatten_to_vector(tree), state)

    def decode_bytes(self, data: bytes,
                     state: Optional[PipelineState] = None) -> np.ndarray:
        """Wire bytes -> flat float32 vector.  Self-describing payloads
        decode from their own header (honoring whatever pipeline the sender
        chose); legacy payloads decode through this packetizer's pipeline.
        Raises ``WireDecodeError`` for anything malformed."""
        if self.pipeline.self_describing:
            vec, _ = decode_payload(data, state)
            return vec
        return self.pipeline.decode(data, state)

    def to_packets(self, tree: Any, addr: str, txn: int = 0,
                   state: Optional[PipelineState] = None) -> list[Packet]:
        return packetize(self.encode_bytes(tree, state), addr, txn, self.mtu)

    def from_packets(self, packets: dict[int, Packet], template: Any,
                     state: Optional[PipelineState] = None) -> Any:
        vec = self.decode_bytes(reassemble(packets), state)
        return unflatten_from_vector(vec, template)

    def wire_bytes(self, tree: Any,
                   state: Optional[PipelineState] = None) -> int:
        """Total bytes on the wire for this tree under this pipeline + MTU.

        Computed arithmetically (payload bytes + one header per packet)
        instead of materializing a throwaway packet list just to sum sizes.
        Measurement is side-effect-free: the encode runs on a *copy* of
        ``state``, so sizing a transmission never advances a live EF
        residual that the real send then compensates with.
        """
        data = self.encode_bytes(tree,
                                 state.copy() if state is not None else None)
        payload_max = self.mtu - _IP_UDP_OVERHEAD
        if payload_max <= 0:
            raise ValueError("mtu too small")
        total = max(1, -(-len(data) // payload_max))
        return len(data) + total * HEADER_BYTES
