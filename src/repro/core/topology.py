"""Topology engine: how a fleet is *wired*, as a pluggable policy.

The paper validates MUDP on a 3-node star and defers "a larger Federated
learning system"; every layer since (transports, wire pipelines, the
event-driven orchestrator) kept the star hardwired in ``build_fleet``.
This module makes the wiring a registry-keyed abstraction — the same
idiom as transports (``repro.core.transport``) and wire stages
(``repro.core.wire``) — with three built-ins:

* ``star`` — the paper's topology, **bit-identical** to the historical
  ``build_fleet`` wiring (the 24 orchestrator-equivalence digests and the
  fleet replay digests pin this).
* ``hier`` — a two-tier tree: clients are partitioned into *cells*, each
  served by an **edge aggregator** that runs a local FedAvg round over its
  cell through a nested :class:`~repro.core.server.ServerCore` and
  forwards one merged, weight-carrying update upstream.  The root link
  carries O(aggregators) traffic instead of O(clients) — *the*
  architecture for the million-client north star.  The root tier is a
  regular :class:`~repro.core.rounds.FederatedSystem`, so PR 4's sync
  *and* async scheduling both work above the edges unchanged.
* ``gossip`` — serverless peer-to-peer federation (PeerFL-style): clients
  exchange updates over the existing Transport API on a seeded neighbor
  graph and mix locally; there is no server node anywhere in the
  simulation.

Every *hop* composes independently with the PR 5 wire-plane: a topology
publishes its hop names (``Topology.hops``) and
``FleetConfig.hops`` carries per-hop pipeline specs, e.g. ::

    FleetConfig(topology="hier", cells=8,
                hops="client->edge: topk(0.01)|int8(1024); "
                     "edge->root: delta")

Per-hop traffic is accounted by :meth:`Simulator.label_hop`
(``sim.hop_bytes``), which is how ``benchmarks/topology_bench.py`` shows
the root link shrinking ~linearly in aggregator count.

See ``docs/TOPOLOGY.md`` for diagrams and guidance on when each topology
wins.
"""

from __future__ import annotations

import abc
import dataclasses
import random
from typing import Any, Callable, Optional

import numpy as np

from repro.core.packetizer import (flatten_to_vector, packetize,
                                   unflatten_from_vector)
from repro.core.rounds import FederatedSystem, FLClient, FLConfig
from repro.core.scheduling import SyncScheduler
from repro.core.server import (TRAINING, ClientSession, RoundResult,
                               ServerCore)
from repro.core.simulator import Simulator
from repro.core.flow import maybe_flow
from repro.core.transport import Transport, make_transport
from repro.core.wire import (Pipeline, WireDecodeError, WireError,
                             decode_payload as wire_decode_payload,
                             legacy_pipeline, parse_hop_specs, parse_pipeline)


# --------------------------------------------------------------------------
# The abstraction + registry
# --------------------------------------------------------------------------
class Topology(abc.ABC):
    """How profiles become a wired simulator + a runnable federation.

    ``hops`` are the directed link classes this topology creates; each may
    carry its own wire-pipeline spec (``FleetConfig.hops``).
    ``uplink_hop`` / ``downlink_hop`` name the hops the legacy
    ``FleetConfig.uplink`` / ``downlink`` shorthands map onto.
    """

    name: str = "abstract"
    hops: tuple[str, ...] = ()
    uplink_hop: Optional[str] = None
    downlink_hop: Optional[str] = None

    @abc.abstractmethod
    def build(self, fleet, profiles: list, global_params: Any,
              train_fn_factory: Callable, fl_cfg: Optional[FLConfig]
              ) -> tuple[Simulator, Any]:
        """Wire ``profiles`` into a fresh Simulator and return
        ``(sim, system)`` where ``system`` has the FederatedSystem run
        surface (``run_round`` / ``run_rounds`` / ``global_params`` /
        ``history`` / ``on_round_end``)."""


_REGISTRY: dict[str, Callable[[], Topology]] = {}


def register_topology(name: str, factory: Callable[[], Topology], *,
                      overwrite: bool = False) -> None:
    """Register ``factory`` under ``name`` (the transport-registry idiom:
    silent shadowing of a built-in would invalidate benchmarks)."""
    if not overwrite and name in _REGISTRY:
        raise ValueError(f"topology {name!r} is already registered "
                         f"(pass overwrite=True to replace it)")
    _REGISTRY[name] = factory


def make_topology(name: str) -> Topology:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown topology {name!r}; registered topologies: "
            f"{available_topologies()}") from None
    return factory()


def available_topologies() -> list[str]:
    return sorted(_REGISTRY)


def topology_hops(name: str) -> tuple[str, ...]:
    """The hop names ``name`` wires (for per-hop spec validation)."""
    return make_topology(name).hops


def resolved_hop_specs(fleet, topo: Topology) -> dict[str, str]:
    """Merge ``fleet.hops`` with the legacy ``uplink``/``downlink``
    shorthands into one ``{hop: pipeline spec}`` map for ``topo``.
    ``FleetConfig`` already rejects setting both spellings at once."""
    out: dict[str, str] = {}
    if fleet.hops is not None:
        out = parse_hop_specs(fleet.hops, known_hops=topo.hops)
    if fleet.uplink is not None:
        if topo.uplink_hop is None:
            raise ValueError(f"topology {topo.name!r} has no uplink hop; "
                             f"use hops= with one of {sorted(topo.hops)}")
        out[topo.uplink_hop] = fleet.uplink
    if fleet.downlink is not None:
        if topo.downlink_hop is None:
            raise ValueError(f"topology {topo.name!r} has no downlink hop; "
                             f"use hops= with one of {sorted(topo.hops)}")
        out[topo.downlink_hop] = fleet.downlink
    return out


# --------------------------------------------------------------------------
# star — the paper's wiring, bit-identical to the historical build_fleet
# --------------------------------------------------------------------------
class StarTopology(Topology):
    """N clients around one server: exactly the pre-topology-engine
    ``build_fleet`` wiring (same link draws, same construction order, same
    FLConfig overrides), pinned by the fleet replay digests."""

    name = "star"
    hops = ("client->server", "server->client")
    uplink_hop = "client->server"
    downlink_hop = "server->client"

    def build(self, fleet, profiles, global_params, train_fn_factory,
              fl_cfg):
        from repro.core.fleet import links_for
        fl_cfg = fl_cfg if fl_cfg is not None else FLConfig()
        hop = resolved_hop_specs(fleet, self)
        transport = fl_cfg.transport
        up, down = hop.get(self.uplink_hop), hop.get(self.downlink_hop)
        if up is not None or down is not None:
            transport = dataclasses.replace(
                transport,
                uplink=up if up is not None else transport.uplink,
                downlink=down if down is not None else transport.downlink)
        fl_cfg = dataclasses.replace(
            fl_cfg,
            transport=transport,
            participation_fraction=fleet.participation_fraction,
            min_participants=fleet.min_participants,
            participation_seed=fleet.seed,
            round_deadline_ns=fleet.round_deadline_ns,
            mode=fleet.mode,
            buffer_k=fleet.buffer_k,
            batch_wire=fleet.batch_wire,
            control=fleet.control,
            control_args=fleet.control_args,
        )
        sim = Simulator(engine=fleet.engine)
        clients = []
        for i, p in enumerate(profiles):
            up_l, down_l = links_for(p)
            sim.connect(p.addr, fleet.server_addr, up_l, down_l)
            sim.label_hop(p.addr, fleet.server_addr, self.uplink_hop)
            sim.label_hop(fleet.server_addr, p.addr, self.downlink_hop)
            clients.append(FLClient(p.addr, train_fn_factory(i, p),
                                    train_time_ns=p.train_time_ns,
                                    weight=p.weight,
                                    cadence_ns=p.cadence_ns))
        system = FederatedSystem(sim, fleet.server_addr, clients,
                                 global_params, fl_cfg)
        return sim, system


# --------------------------------------------------------------------------
# hier — two-tier tree with edge aggregators
# --------------------------------------------------------------------------
def edge_server_addr(m: int) -> str:
    """The edge's cell-facing (server-plane) address."""
    return f"10.2.0.{m + 1}"


def edge_client_addr(m: int) -> str:
    """The edge's root-facing (client-plane) address.  Separate from the
    server plane because persistent receivers consume every DATA packet on
    their node: one node cannot host both the cell's server receiver and
    the edge's root-downlink receiver."""
    return f"10.3.0.{m + 1}"


def _edge_train_stub(params, round_idx, client):
    raise RuntimeError("edge aggregators do not run local training; their "
                       "'training' step is the nested cell round "
                       "(ServerCore.train_override)")


class CellScheduler(SyncScheduler):
    """The sync barrier, driven by callbacks instead of ``sim.run()``.

    The edge tier runs one of these per cell *concurrently over one
    simulator*, so the barrier cannot own the event loop the way
    ``SyncScheduler.run_round`` does.  ``start_round`` opens the barrier
    (session-scoped txn pair — many cells overlap in flight); when it
    resolves (every sampled cell client resolved, or the cell deadline
    fires) the aggregated :class:`RoundResult` is emitted into the cell
    core's history and handed to ``on_complete``.
    """

    mode = "cell"

    def __init__(self, core: ServerCore):
        super().__init__(core)
        self._on_complete: Optional[Callable[[RoundResult], None]] = None

    def start_round(self, params: Any,
                    on_complete: Callable[[RoundResult], None]) -> None:
        if self._round_open:
            # Superseded: an async root watchdog re-entered the edge while
            # the previous cell round was still in flight.  Abandon the old
            # barrier; its straggler uplinks fold into the next round's
            # late buffer like any other cutoff.
            self._abandon()
        self.core.global_params = params
        self._on_complete = on_complete
        # clear_sessions=False: previous cell rounds' sessions stay
        # registered so their straggler uplinks reach on_uplink (-> late
        # buffer) instead of vanishing; resolved sessions are dropped
        # eagerly below, bounding the registries.
        self._begin_round(None, txn_pair=self.core.new_txn_pair(),
                          clear_sessions=False)
        if self._round_open and not self._roster:
            # Every cell client is benched: resolve immediately so the
            # parent barrier is never held hostage by an empty cell.
            self._finalize()

    def _abandon(self) -> None:
        self._round_open = False
        if self._deadline_timer is not None:
            self._deadline_timer.cancel()
            self._deadline_timer = None
        self._on_complete = None

    def _finalize(self) -> None:
        super()._finalize()
        cb, self._on_complete = self._on_complete, None
        result = self.core.emit_result(self._build_result())
        if cb is not None:
            cb(result)

    # Resolved sessions are dropped eagerly: cell rounds never call
    # clear_sessions() between rounds (the registries would otherwise grow
    # with every overlapping round), and a receiver delivers each txn
    # exactly once so a resolved session can never match traffic again.
    def on_uplink(self, session, addr, txn, vec) -> None:
        super().on_uplink(session, addr, txn, vec)
        if session is not None:
            self.core.drop_session(session)

    def on_session_failed(self, session) -> None:
        if session.round_idx != self._round_idx:
            # A sender of an earlier (abandoned or finalized) cell round
            # exhausted its retries mid-overlap.  SyncScheduler keys
            # failures by address, so without this guard the stale failure
            # would resolve the client's *current* session as failed.
            self.core.drop_session(session)
            return
        super().on_session_failed(session)
        self.core.drop_session(session)

    def run_round(self, round_idx=None):
        raise RuntimeError("cell rounds are driven by the parent tier; "
                           "use start_round()")

    def run_rounds(self, n):
        raise RuntimeError("cell rounds are driven by the parent tier; "
                           "use start_round()")


class EdgeAggregator:
    """One cell's aggregator: a nested ServerCore + cell barrier on the
    server plane, an FLClient of the root tier on the client plane."""

    def __init__(self, idx: int, client: FLClient, core: ServerCore,
                 scheduler: CellScheduler):
        self.idx = idx
        self.client = client          # root-facing identity
        self.core = core              # cell-facing ServerCore
        self.scheduler = scheduler

    @property
    def addr(self) -> str:
        return self.client.addr

    @property
    def server_addr(self) -> str:
        return self.core.server_addr


class HierSystem:
    """The FederatedSystem surface over a two-tier tree.

    The *root* is a regular :class:`FederatedSystem` whose clients are the
    edge aggregators; its core's ``train_override`` turns each edge's
    "training" step into a full nested cell round:

        root downlink -> edge -> cell broadcast -> cell barrier ->
        local FedAvg -> one merged update (weight = arrived cell mass)
        -> edge uplink -> root aggregation

    ``run_round`` / ``run_rounds`` / ``global_params`` / ``history`` /
    ``on_round_end`` delegate to the root, so benchmarks and examples
    treat a tree exactly like a star.  Per-cell round histories live on
    each edge's nested core (``edges[m].core.history``).
    """

    def __init__(self, sim: Simulator, root: FederatedSystem,
                 edges: list[EdgeAggregator]):
        self.sim = sim
        self.root = root
        self.edges = edges
        self._by_addr = {e.addr: e for e in edges}
        root.core.train_override = self._on_edge_model

    # -- the nested-round train override --------------------------------------
    def _on_edge_model(self, session: ClientSession) -> None:
        """Root downlink delivered to an edge: run its cell round; the
        merged model uplinks when the cell barrier resolves."""
        edge = self._by_addr[session.addr]
        session.state = TRAINING
        received = session.client.params

        def _cell_done(result: RoundResult) -> None:
            merged = edge.core.global_params
            weight = 0.0
            for addr in result.arrived:
                c = edge.core.pool.clients.get(addr)
                if c is not None:
                    weight += c.weight
            # The merged update carries the cell's arrived mass upstream so
            # root FedAvg over edges equals client-weighted FedAvg over the
            # union.  An empty-handed cell forwards its unchanged model
            # with weight 0 (dropped by apply_aggregation) so the root
            # barrier still resolves.
            session.client.weight = weight
            self.root.core.uplink_update(session, received, merged)

        edge.scheduler.start_round(received, _cell_done)

    # -- the stable surface ---------------------------------------------------
    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        return self.root.run_round(round_idx)

    def run_rounds(self, n: int) -> list[RoundResult]:
        return self.root.run_rounds(n)

    @property
    def global_params(self) -> Any:
        return self.root.global_params

    @global_params.setter
    def global_params(self, value: Any) -> None:
        self.root.global_params = value

    @property
    def history(self) -> list[RoundResult]:
        return self.root.history

    @property
    def on_round_end(self):
        return self.root.on_round_end

    @on_round_end.setter
    def on_round_end(self, cb) -> None:
        self.root.on_round_end = cb

    @property
    def pool(self):
        return self.root.pool

    def edge_for(self, client_addr: str) -> Optional[EdgeAggregator]:
        for e in self.edges:
            if client_addr in e.core.pool.clients:
                return e
        return None


class HierTopology(Topology):
    """Two-tier tree: ``cells`` edge aggregators between the clients and
    the root.  Cell membership is round-robin (``FleetConfig.cell_of``) so
    every cell gets the same cohort mix; edge<->root links are drawn from
    ``FleetConfig.edge_cohort`` (default ``fiber`` — aggregators are
    infrastructure, not phones) on their own RNG stream, so client link
    draws stay bit-identical to the star's."""

    name = "hier"
    hops = ("client->edge", "edge->client", "edge->root", "root->edge")
    uplink_hop = "edge->root"
    downlink_hop = "root->edge"

    def build(self, fleet, profiles, global_params, train_fn_factory,
              fl_cfg):
        from repro.core.fleet import links_for
        fl_cfg = fl_cfg if fl_cfg is not None else FLConfig()
        hop = resolved_hop_specs(fleet, self)
        cells = fleet.cells
        base_t = fl_cfg.transport

        root_transport = dataclasses.replace(
            base_t,
            uplink=hop.get("edge->root"),
            downlink=hop.get("root->edge"))
        root_cfg = dataclasses.replace(
            fl_cfg,
            transport=root_transport,
            participation_fraction=1.0,    # the root always serves every edge
            min_participants=1,
            participation_seed=fleet.seed,
            # The deadline knob bounds the *cell* round; the root tier gets
            # double the budget so a cell that used its whole allowance
            # (straggler cutoff at exactly the deadline) can still uplink
            # its merged update before the root barrier closes.
            round_deadline_ns=(None if fleet.round_deadline_ns is None
                               else 2 * fleet.round_deadline_ns),
            mode=fleet.mode,
            # An async root can never buffer more than one update per edge
            # in a window, so a star-calibrated buffer_k would stall.
            buffer_k=min(fleet.buffer_k, cells),
            batch_wire=fleet.batch_wire,
            control=fleet.control,
            control_args=fleet.control_args,
        )
        cell_transport = dataclasses.replace(
            base_t,
            kind=fleet.cell_transport if fleet.cell_transport is not None
            else base_t.kind,
            uplink=hop.get("client->edge"),
            downlink=hop.get("edge->client"))

        sim = Simulator(engine=fleet.engine)
        edge_profs = sample_edge_profiles(fleet, cells)
        for m in range(cells):
            up_l, down_l = links_for(edge_profs[m])
            sim.connect(edge_profs[m].addr, fleet.server_addr, up_l, down_l)
            sim.label_hop(edge_profs[m].addr, fleet.server_addr,
                          "edge->root")
            sim.label_hop(fleet.server_addr, edge_profs[m].addr,
                          "root->edge")
        cell_members: list[list[tuple[int, Any]]] = [[] for _ in range(cells)]
        for i, p in enumerate(profiles):
            m = fleet.cell_of(i)
            up_l, down_l = links_for(p)
            sim.connect(p.addr, edge_server_addr(m), up_l, down_l)
            sim.label_hop(p.addr, edge_server_addr(m), "client->edge")
            sim.label_hop(edge_server_addr(m), p.addr, "edge->client")
            cell_members[m].append((i, p))

        edges: list[EdgeAggregator] = []
        root_clients: list[FLClient] = []
        for m in range(cells):
            cell_cfg = dataclasses.replace(
                fl_cfg,
                transport=cell_transport,
                mode="sync",               # the cell barrier is CellScheduler
                participation_fraction=fleet.participation_fraction,
                min_participants=fleet.min_participants,
                # Distinct per-cell stream (ints only: Random.random()-level
                # stability); one shared seed would correlate roster draws.
                participation_seed=fleet.seed * 1009 + m + 1,
                round_deadline_ns=fleet.round_deadline_ns,
                batch_wire=fleet.batch_wire,
                # Per-hop policies: each cell's ServerCore runs its own
                # controller instance over its own clients' telemetry, and
                # the root runs one over the edge uplinks (root_cfg above).
                control=fleet.control,
                control_args=fleet.control_args,
            )
            cell_clients = [
                FLClient(p.addr, train_fn_factory(i, p),
                         train_time_ns=p.train_time_ns,
                         weight=p.weight,
                         cadence_ns=p.cadence_ns)
                for i, p in cell_members[m]]
            core = ServerCore(sim, edge_server_addr(m), cell_clients,
                              global_params, cell_cfg)
            scheduler = CellScheduler(core)
            edge_client = FLClient(edge_profs[m].addr, _edge_train_stub,
                                   train_time_ns=0, weight=1.0,
                                   cadence_ns=0)
            edges.append(EdgeAggregator(m, edge_client, core, scheduler))
            root_clients.append(edge_client)

        root = FederatedSystem(sim, fleet.server_addr, root_clients,
                               global_params, root_cfg)
        return sim, HierSystem(sim, root, edges)


def sample_edge_profiles(fleet, cells: int) -> list:
    """Deterministic edge<->root link draws from ``fleet.edge_cohort``.

    A dedicated RNG stream (like the cadence draws in
    ``sample_profiles``): adding aggregators must not re-roll any client's
    link profile for a given seed.
    """
    from repro.core.fleet import ClientProfile
    spec = fleet.cohort_specs()[fleet.edge_cohort]
    rng = random.Random(hash((int(fleet.seed), 0xED6E)))

    def u(lo: float, hi: float) -> float:
        return lo + (hi - lo) * rng.random()

    out = []
    for m in range(cells):
        up = u(*spec.up_rate_bps)
        delay = int(u(*spec.delay_ns))
        out.append(ClientProfile(
            addr=edge_client_addr(m),
            cohort=spec.name,
            up_rate_bps=up,
            down_rate_bps=up * spec.down_up_ratio,
            delay_ns=delay,
            jitter_ns=int(spec.jitter_frac * delay),
            loss_p=u(*spec.loss_p),
            bursty=spec.bursty,
            train_time_ns=0,
            weight=1.0,
            # Offset past every client link seed for this fleet seed.
            seed=int(fleet.seed) * 1_000_003 + (fleet.n_clients + m) * 4,
            cadence_ns=0,
        ))
    return out


# --------------------------------------------------------------------------
# gossip — serverless peer-to-peer federation
# --------------------------------------------------------------------------
def neighbor_graph(n: int, k: int, seed: int) -> list[set[int]]:
    """A seeded, connected, roughly ``k``-regular undirected graph.

    A ring guarantees connectivity; seeded chords (``Random.random()``
    only, so the draw is bit-stable across Python versions) raise every
    node's degree to at least ``min(k, n-1)``.
    """
    if n < 2:
        raise ValueError("a gossip graph needs at least 2 clients")
    adj: list[set[int]] = [set() for _ in range(n)]
    for i in range(n):
        j = (i + 1) % n
        if i != j:
            adj[i].add(j)
            adj[j].add(i)
    rng = random.Random(hash((int(seed), 0x605519)))
    for i in range(n):
        want = min(k, n - 1)
        attempts = 0
        while len(adj[i]) < want and attempts < 64 * n:
            j = int(rng.random() * n)
            attempts += 1
            if j != i and j not in adj[i]:
                adj[i].add(j)
                adj[j].add(i)
    return adj


class GossipSystem:
    """Serverless federation over a fixed neighbor graph.

    Each round every client trains locally, ships its model to its
    neighbors through the regular Transport API (MUDP NACK-repair, UDP
    zero-fill, FEC — all of it works peer-to-peer unchanged), and mixes
    whatever arrived with its own model, weighted by the senders'
    aggregation mass.  ``global_params`` is the *evaluation* consensus
    (weighted mean over client models); it never travels on the wire and
    there is no server node in the simulation.
    """

    def __init__(self, sim: Simulator, profiles: list,
                 adj: list[set[int]], global_params: Any,
                 train_fn_factory: Callable, cfg: FLConfig,
                 pipeline: Pipeline):
        self.sim = sim
        self.cfg = cfg
        self.adj = adj
        self.pipeline = pipeline
        self.transport: Transport = maybe_flow(
            sim, make_transport(cfg.transport.kind))
        self.clients = [
            FLClient(p.addr, train_fn_factory(i, p),
                     train_time_ns=p.train_time_ns, weight=p.weight)
            for i, p in enumerate(profiles)]
        for c in self.clients:
            c.params = global_params
        self._template = global_params
        self._n_params = int(flatten_to_vector(global_params).size)
        self._addr_idx = {c.addr: i for i, c in enumerate(self.clients)}
        # Per-client mailbox: sender index -> decoded vector, cleared at
        # each round start.  A straggler delivery from the previous round
        # lands in the current mailbox — one round of gossip staleness,
        # the p2p analogue of the server's late buffer.
        self._inbox: list[dict[int, np.ndarray]] = [
            {} for _ in self.clients]
        self.history: list[RoundResult] = []
        self.on_round_end: Optional[Callable] = None
        self.decode_errors = 0
        self.retx_total = 0
        self._failed_legs = 0
        self._round_idx = -1
        # Optional repro.core.client_compute.BatchTrainer: every client's
        # training input is its round-start model, so the whole round is
        # submitted up front and trains as one vmapped batch at the first
        # timer fire.  None = the per-client train_fn path.
        self.batch_trainer: Optional[Any] = None
        self._rx = [self.transport.create_receiver(
            sim, sim.node(c.addr), cfg.transport, self._make_deliver(i))
            for i, c in enumerate(self.clients)]

    # -- receive side ---------------------------------------------------------
    def _make_deliver(self, i: int):
        def _cb(d) -> None:
            if not d.complete and not self.transport.caps.partial_delivery:
                return
            j = self._addr_idx.get(d.sender_addr)
            if j is None:
                return
            self._inbox[i][j] = self._decode(d.reassemble())
        return _cb

    def _decode(self, data: bytes) -> np.ndarray:
        """ServerCore.decode_vec's contract, peer-side: self-describing
        payloads decode from their header; failures degrade explicitly to
        a zero vector + counter."""
        try:
            if self.pipeline.self_describing:
                vec, negotiated = wire_decode_payload(data)
                if negotiated.caps.delta_domain:
                    raise WireDecodeError(
                        "gossip mixes weight-domain models; a delta-domain "
                        "payload has no reference to apply against")
            else:
                vec = self.pipeline.decode(data)
        except WireDecodeError:
            self.decode_errors += 1
            vec = np.zeros(self._n_params, dtype=np.float32)
        if vec.size < self._n_params:
            vec = np.concatenate(
                [vec, np.zeros(self._n_params - vec.size, np.float32)])
        return vec[:self._n_params]

    # -- send side ------------------------------------------------------------
    def _note_retx(self, sender) -> None:
        self.retx_total += getattr(sender.stats, "retransmissions", 0)

    def _note_fail(self, sender) -> None:
        self._note_retx(sender)
        self._failed_legs += 1

    def _train_and_send(self, i: int) -> None:
        c = self.clients[i]
        if self.batch_trainer is not None:
            _, new_params, metrics = self.batch_trainer.collect(
                (self._round_idx, i))
        else:
            new_params, metrics = c.train_fn(c.params, self._round_idx, c)
        c.metrics_history.append(metrics)
        c.params = new_params
        vec = flatten_to_vector(new_params)
        node = self.sim.node(c.addr)
        for j in sorted(self.adj[i]):
            data = self.pipeline.encode(vec, None)
            packets = packetize(data, c.addr, self._round_idx,
                                self.cfg.transport.mtu)
            self.transport.create_sender(
                self.sim, node, self.sim.node(self.clients[j].addr),
                packets, self.cfg.transport,
                on_complete=self._note_retx, on_fail=self._note_fail,
            ).start()

    # -- the round ------------------------------------------------------------
    def run_round(self, round_idx: Optional[int] = None) -> RoundResult:
        if round_idx is not None:
            raise ValueError("gossip numbers its own rounds (they key the "
                             "wire transactions)")
        self._round_idx += 1
        stats0 = dict(self.sim.stats)
        retx0 = self.retx_total
        self._failed_legs = 0
        t0 = self.sim.now_ns
        for box in self._inbox:
            box.clear()
        if self.batch_trainer is not None:
            for i, c in enumerate(self.clients):
                self.batch_trainer.submit((self._round_idx, i), c.addr,
                                          c.params, self._round_idx)
        for i, c in enumerate(self.clients):
            self.sim.schedule(c.train_time_ns,
                              lambda i=i: self._train_and_send(i))
        self.sim.run()

        arrived = []
        mixed_in = 0
        for i, c in enumerate(self.clients):
            own = flatten_to_vector(c.params)
            num = c.weight * own
            den = c.weight
            for j in sorted(self._inbox[i]):
                w = self.clients[j].weight
                num = num + w * self._inbox[i][j]
                den += w
            mixed_in += len(self._inbox[i])
            if self._inbox[i]:
                arrived.append(c.addr)
            c.params = unflatten_from_vector(
                (num / den).astype(np.float32), self._template)

        s1 = self.sim.stats
        result = RoundResult(
            round_idx=self._round_idx,
            duration_ns=self.sim.now_ns - t0,
            arrived=sorted(arrived),
            failed=[],
            skipped_unhealthy=[],
            late_folded=0,
            bytes_sent=s1["bytes_sent"] - stats0["bytes_sent"],
            packets_sent=s1["packets_sent"] - stats0["packets_sent"],
            packets_dropped=(s1["packets_dropped"]
                             - stats0["packets_dropped"]),
            retransmissions=self.retx_total - retx0,
            roster=sorted(c.addr for c in self.clients),
            data_packets=s1.get("sent_data", 0) - stats0.get("sent_data", 0),
            nack_packets=s1.get("sent_nack", 0) - stats0.get("sent_nack", 0),
            parity_packets=(s1.get("sent_parity", 0)
                            - stats0.get("sent_parity", 0)),
            metrics={
                "neighbors_mean": mixed_in / len(self.clients),
                "failed_legs": self._failed_legs,
                "decode_errors": self.decode_errors,
            },
        )
        self.history.append(result)
        if self.on_round_end is not None:
            self.on_round_end(result, self.global_params)
        return result

    def run_rounds(self, n: int) -> list[RoundResult]:
        return [self.run_round() for _ in range(n)]

    @property
    def global_params(self) -> Any:
        num = None
        den = 0.0
        for c in self.clients:
            v = c.weight * flatten_to_vector(c.params)
            num = v if num is None else num + v
            den += c.weight
        return unflatten_from_vector((num / den).astype(np.float32),
                                     self._template)


class GossipTopology(Topology):
    """Serverless: a seeded ~``neighbors``-regular peer graph, one link
    pair per edge (each direction drawn from the *sender's* profile), and
    a :class:`GossipSystem` driving train/exchange/mix rounds."""

    name = "gossip"
    hops = ("peer->peer",)
    uplink_hop = "peer->peer"
    downlink_hop = None

    def build(self, fleet, profiles, global_params, train_fn_factory,
              fl_cfg):
        from repro.core.fleet import _loss_model
        fl_cfg = fl_cfg if fl_cfg is not None else FLConfig()
        if fl_cfg.send_deltas or fl_cfg.error_feedback:
            raise ValueError(
                "gossip cannot ship deltas or run error feedback: peers mix "
                "full models and hold no per-peer encoder state")
        hop = resolved_hop_specs(fleet, self)
        spec = hop.get("peer->peer")
        t = fl_cfg.transport
        pipeline = (parse_pipeline(spec) if spec is not None
                    else legacy_pipeline(t.codec, t.codec_kwargs))
        if pipeline.caps.delta_domain or pipeline.caps.stateful:
            raise ValueError(
                "gossip requires a stateless weight-domain pipeline: peers "
                "mix full models and hold no per-peer encoder state "
                "(delta/ef stages cannot ride this hop)")
        cfg = fl_cfg

        from repro.core.channel import Link
        sim = Simulator(engine=fleet.engine)
        adj = neighbor_graph(fleet.n_clients, fleet.neighbors, fleet.seed)
        seen = set()
        for i in range(fleet.n_clients):
            for j in sorted(adj[i]):
                if (j, i) in seen or (i, j) in seen:
                    continue
                seen.add((i, j))
                pi, pj = profiles[i], profiles[j]
                sij = hash((int(fleet.seed), 0x60551B, i, j)) \
                    & 0x7FFFFFFFFFFF
                link_ij = Link(pi.up_rate_bps, pi.delay_ns,
                               _loss_model(pi, sij),
                               jitter_ns=pi.jitter_ns, jitter_seed=sij + 1)
                link_ji = Link(pj.up_rate_bps, pj.delay_ns,
                               _loss_model(pj, sij + 2),
                               jitter_ns=pj.jitter_ns, jitter_seed=sij + 3)
                sim.connect(pi.addr, pj.addr, link_ij, link_ji)
                sim.label_hop(pi.addr, pj.addr, "peer->peer")
                sim.label_hop(pj.addr, pi.addr, "peer->peer")
        system = GossipSystem(sim, profiles, adj, global_params,
                              train_fn_factory, cfg, pipeline)
        return sim, system


register_topology("star", StarTopology)
register_topology("hier", HierTopology)
register_topology("gossip", GossipTopology)
