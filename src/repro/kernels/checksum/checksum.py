"""Pallas TPU kernel: ChunkSum-32 packet-payload checksum.

Single pass over the payload: each grid step loads a (8, 1024) int32 tile,
forms the weighted and unweighted partial sums on the VPU, and accumulates
them into two scalar outputs (TPU grid steps execute sequentially, so
read-modify-write on the output ref across steps is the standard
accumulator pattern; step 0 initializes).

int32 wraparound is part of the checksum definition (see ref.py), so the
adds are exact on any backend.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.checksum.ref import WEIGHT_PERIOD

TILE_R, TILE_C = 8, 1024
TILE = TILE_R * TILE_C


def _checksum_kernel(x_ref, acc_ref):
    step = pl.program_id(0)
    x = x_ref[...]                                     # (8, 1024) int32
    base = step * TILE
    idx = base + (jax.lax.broadcasted_iota(jnp.int32, (TILE_R, TILE_C), 0)
                  * TILE_C
                  + jax.lax.broadcasted_iota(jnp.int32, (TILE_R, TILE_C), 1))
    w = (idx % WEIGHT_PERIOD) + 1
    a_part = jnp.sum(x, dtype=jnp.int32)
    b_part = jnp.sum(w * x, dtype=jnp.int32)

    @pl.when(step == 0)
    def _init():
        acc_ref[0] = a_part
        acc_ref[1] = b_part

    @pl.when(step != 0)
    def _acc():
        acc_ref[0] = acc_ref[0] + a_part
        acc_ref[1] = acc_ref[1] + b_part


@functools.partial(jax.jit, static_argnames=("interpret",))
def checksum_pallas(x_i32: jax.Array, *, interpret: bool = True
                    ) -> jax.Array:
    """x_i32: (N,) int32 byte values -> uint32-style checksum as int32.

    N is padded to the tile size with zeros; zero bytes contribute nothing
    to either sum, so padding never changes the checksum.
    """
    n = x_i32.shape[0]
    pad = (-n) % TILE
    if pad:
        x_i32 = jnp.pad(x_i32, (0, pad))
    tiles = (n + pad) // TILE
    acc = pl.pallas_call(
        _checksum_kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((TILE_R, TILE_C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.int32),
        interpret=interpret,
    )(x_i32.reshape(tiles * TILE_R, TILE_C))
    return (acc[0] & 0xFFFF) | ((acc[1] & 0xFFFF) << 16)
