"""Jit'd wrapper: checksum raw bytes on device."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.checksum.checksum import checksum_pallas
from repro.kernels.checksum.ref import chunksum32_np


def checksum_bytes(data: bytes, *, interpret: bool = True) -> int:
    x = jnp.asarray(np.frombuffer(data, dtype=np.uint8).astype(np.int32))
    return int(np.uint32(np.asarray(checksum_pallas(x, interpret=interpret))))


def checksum_bytes_ref(data: bytes) -> int:
    return chunksum32_np(np.frombuffer(data, dtype=np.uint8))
