"""Pure-numpy/jnp oracle for the packet checksum kernel.

ChunkSum-32 (this framework's on-device payload checksum): over byte values
x_i (widened to int32),

  A = sum_i x_i                    (int32 wraparound)
  B = sum_i ((i mod 8191) + 1)*x_i (int32 wraparound)
  checksum = (A & 0xFFFF) | ((B & 0xFFFF) << 16)

Weights are bounded so every product fits int32 exactly; wrap-around adds are
deterministic and order-independent — unlike Adler-32's sequential prefix
form, every term is independent, which is what makes it a TPU-friendly
single-pass reduction. Used to verify payload integrity on-device before
hand-off to the NIC; the wire codec keeps zlib.adler32.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

WEIGHT_PERIOD = 8191


def chunksum32_np(data: np.ndarray) -> int:
    """data: uint8 array."""
    x = data.astype(np.uint32)
    idx = np.arange(x.size, dtype=np.uint32)
    w = (idx % WEIGHT_PERIOD) + 1
    A = np.uint32(x.sum(dtype=np.uint64) & 0xFFFFFFFF)
    B = np.uint32((w.astype(np.uint64) * x).sum(dtype=np.uint64)
                  & 0xFFFFFFFF)
    return int((A & 0xFFFF) | ((B & 0xFFFF) << np.uint32(16)))


def chunksum32_jnp(x_i32: jnp.ndarray) -> jnp.ndarray:
    """x_i32: int32 array of byte values (0..255)."""
    idx = jnp.arange(x_i32.shape[0], dtype=jnp.int32)
    w = (idx % WEIGHT_PERIOD) + 1
    A = jnp.sum(x_i32, dtype=jnp.int32)
    B = jnp.sum(w * x_i32, dtype=jnp.int32)
    return (A & 0xFFFF) | ((B & 0xFFFF) << 16)
