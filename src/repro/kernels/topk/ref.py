"""Pure-jnp oracle for the batched top-k gather/scatter kernels.

Mirrors the numpy batch path in ``repro.core.wire.TopKStage``:
``take_along_axis`` for gather, zero-init + row-wise scatter for decode.
Duplicate indices are undefined here (``.at[].set`` order) — the wire
never produces them; the parity tests use unique sorted indices.
"""

from __future__ import annotations

import jax.numpy as jnp


def gather_rows(x: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """x: (N, P), idx: (N, K) -> (N, K) values at idx per row."""
    return jnp.take_along_axis(x.astype(jnp.float32),
                               idx.astype(jnp.int32), axis=1)


def scatter_rows(idx: jnp.ndarray, vals: jnp.ndarray, n: int) -> jnp.ndarray:
    """idx/vals: (N, K) -> (N, n), zeros except vals placed at idx."""
    n_items, k_kept = idx.shape
    rows = jnp.repeat(jnp.arange(n_items), k_kept)
    out = jnp.zeros((n_items, n), jnp.float32)
    return out.at[rows, idx.astype(jnp.int32).reshape(-1)].set(
        vals.astype(jnp.float32).reshape(-1))
