"""Pallas TPU kernel: batched top-k gather/scatter over client slabs.

The wire-plane's ``topk`` stage moves values between a dense ``(N, P)``
client slab and its sparse ``(N, K)`` representation: *gather* on encode
(pick each row's K kept values at already-selected indices), *scatter* on
decode (place K values back into a zeroed dense row).  Selection itself
(argpartition) stays on the host — it is data-dependent and cheap — so the
kernels are pure data movement: one grid step per client row, a
``fori_loop`` of dynamically indexed loads/stores inside VMEM.

Scatter writes are sequential within a row, so duplicate indices resolve
last-wins — the same contract as numpy fancy assignment, which keeps the
batch decode bit-identical to the per-item path even on malformed
payloads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(x_ref, idx_ref, out_ref):
    k_kept = idx_ref.shape[1]

    def body(k, carry):
        out_ref[0, k] = x_ref[0, idx_ref[0, k]]
        return carry

    jax.lax.fori_loop(0, k_kept, body, 0)


def _scatter_kernel(idx_ref, vals_ref, out_ref):
    k_kept = idx_ref.shape[1]
    out_ref[...] = jnp.zeros_like(out_ref)

    def body(k, carry):
        out_ref[0, idx_ref[0, k]] = vals_ref[0, k]
        return carry

    jax.lax.fori_loop(0, k_kept, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def topk_gather_pallas(x: jax.Array, idx: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """x: (N, P) f32, idx: (N, K) int32 -> (N, K) f32 values at idx."""
    n_items, _ = x.shape
    k_kept = idx.shape[1]
    return pl.pallas_call(
        _gather_kernel,
        grid=(n_items,),
        in_specs=[
            pl.BlockSpec((1, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((1, k_kept), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, k_kept), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_items, k_kept), jnp.float32),
        interpret=interpret,
    )(x.astype(jnp.float32), idx.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("n", "interpret"))
def topk_scatter_pallas(idx: jax.Array, vals: jax.Array, *, n: int,
                        interpret: bool = True) -> jax.Array:
    """idx/vals: (N, K) -> (N, n) f32, zeros except vals placed at idx."""
    n_items, k_kept = idx.shape
    return pl.pallas_call(
        _scatter_kernel,
        grid=(n_items,),
        in_specs=[
            pl.BlockSpec((1, k_kept), lambda i: (i, 0)),
            pl.BlockSpec((1, k_kept), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_items, n), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), vals.astype(jnp.float32))
