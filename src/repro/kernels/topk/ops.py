"""Jit'd wrappers: batched top-k gather/scatter for the wire batch plane.

``repro.core.wire`` probes this module lazily (``set_batch_backend
("pallas")``); both ops are exact data movement, so the batch contract —
bit-identical to the numpy path — holds by construction and is pinned in
``tests/test_kernel_parity.py``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.topk.topk import topk_gather_pallas, topk_scatter_pallas


def topk_gather(batch, idx, *, interpret: bool = True):
    """batch: (N, P) f32, idx: (N, K) -> (N, K) f32 kept values."""
    return topk_gather_pallas(jnp.asarray(batch, jnp.float32),
                              jnp.asarray(idx).astype(jnp.int32),
                              interpret=interpret)


def topk_scatter(idx, vals, n, *, interpret: bool = True):
    """idx/vals: (N, K) -> dense (N, n) f32 (zeros off the kept set)."""
    return topk_scatter_pallas(jnp.asarray(idx).astype(jnp.int32),
                               jnp.asarray(vals, jnp.float32),
                               n=int(n), interpret=interpret)
