"""Pure-jnp oracle for the blockwise int8 quantization kernel.

Mirrors ``repro.core.compression.quantize_int8`` (the transport codec): per
block of ``block`` values, scale = absmax/127, q = clip(rint(x/scale)).
"""

from __future__ import annotations

import jax.numpy as jnp


def quantize_blockwise(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (nb, block) f32 -> (q int8 (nb, block), scales f32 (nb,))."""
    absmax = jnp.max(jnp.abs(x), axis=1)
    scales = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.rint(x / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales.astype(jnp.float32)


def dequantize_blockwise(q: jnp.ndarray, scales: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scales[:, None]
