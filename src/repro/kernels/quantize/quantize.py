"""Pallas TPU kernel: blockwise absmax int8 quantization (packet payload
compression / quantized gradient aggregation).

Client-side packetization quantizes the full parameter vector before the
wire; at tens of GB this is bandwidth-bound, so the kernel fuses
absmax-reduce + scale + round + cast in one VMEM pass (the jnp reference
makes three).

Layout: the flat vector is viewed as (nb, QBLOCK) rows; each grid step
processes ROWS_PER_TILE rows — (8, 1024) f32 = 32 KiB in, 8 KiB out, VPU
reductions along lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QBLOCK = 1024          # values per quantization block (wire codec contract)
ROWS_PER_TILE = 8      # sublane-aligned rows per grid step


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]                                   # (R, QBLOCK) f32
    absmax = jnp.max(jnp.abs(x), axis=1)             # (R,)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.rint(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...][:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_pallas(x: jax.Array, *, interpret: bool = True
                    ) -> tuple[jax.Array, jax.Array]:
    """x: (nb, QBLOCK) f32 -> (q (nb, QBLOCK) int8, scales (nb,) f32)."""
    nb, blk = x.shape
    assert blk == QBLOCK, (blk, QBLOCK)
    pad = (-nb) % ROWS_PER_TILE
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    rows = nb + pad
    grid = (rows // ROWS_PER_TILE,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_PER_TILE, QBLOCK), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS_PER_TILE, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, QBLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return q[:nb], s[:nb]


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequantize_pallas(q: jax.Array, scales: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    nb, blk = q.shape
    assert blk == QBLOCK
    pad = (-nb) % ROWS_PER_TILE
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        scales = jnp.pad(scales, (0, pad))
    rows = nb + pad
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(rows // ROWS_PER_TILE,),
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, QBLOCK), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_TILE, QBLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, QBLOCK), jnp.float32),
        interpret=interpret,
    )(q, scales.astype(jnp.float32))
    return out[:nb]
