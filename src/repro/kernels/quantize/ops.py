"""Jit'd wrappers: flat-vector int8 quantize/dequantize on device."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize.quantize import (QBLOCK, dequantize_pallas,
                                             quantize_pallas)


def quantize_vector(vec, *, interpret: bool = True):
    """Flat f32 vector -> (q int8 (padded to QBLOCK), scales, n)."""
    vec = jnp.asarray(vec, jnp.float32)
    n = vec.shape[0]
    nb = -(-n // QBLOCK)
    padded = jnp.zeros((nb * QBLOCK,), jnp.float32).at[:n].set(vec)
    q, s = quantize_pallas(padded.reshape(nb, QBLOCK), interpret=interpret)
    return q, s, n


def dequantize_vector(q, scales, n, *, interpret: bool = True):
    out = dequantize_pallas(q, scales, interpret=interpret)
    return out.reshape(-1)[:n]


def quantize_matrix(mat, *, interpret: bool = True):
    """Batched client slab: (N, P) f32 -> (q int8 (N, nb*QBLOCK),
    scales (N, nb)) — the wire ``int8`` stage's batch layout.  Rows are
    independent, so this is one kernel launch over N*nb blocks instead of
    N launches."""
    mat = jnp.asarray(mat, jnp.float32)
    n_items, n = mat.shape
    nb = -(-n // QBLOCK)
    padded = jnp.zeros((n_items, nb * QBLOCK), jnp.float32).at[:, :n].set(mat)
    q, s = quantize_pallas(padded.reshape(n_items * nb, QBLOCK),
                           interpret=interpret)
    return q.reshape(n_items, nb * QBLOCK), s.reshape(n_items, nb)


def dequantize_matrix(q, scales, n, *, interpret: bool = True):
    """Inverse of :func:`quantize_matrix`: -> (N, n) f32."""
    scales = jnp.asarray(scales, jnp.float32)
    n_items, nb = scales.shape
    out = dequantize_pallas(jnp.asarray(q, jnp.int8).reshape(n_items * nb,
                                                             QBLOCK),
                            scales.reshape(-1), interpret=interpret)
    return out.reshape(n_items, nb * QBLOCK)[:, :n]
