"""Jit'd wrappers: flat-vector int8 quantize/dequantize on device."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize.quantize import (QBLOCK, dequantize_pallas,
                                             quantize_pallas)


def quantize_vector(vec, *, interpret: bool = True):
    """Flat f32 vector -> (q int8 (padded to QBLOCK), scales, n)."""
    vec = jnp.asarray(vec, jnp.float32)
    n = vec.shape[0]
    nb = -(-n // QBLOCK)
    padded = jnp.zeros((nb * QBLOCK,), jnp.float32).at[:n].set(vec)
    q, s = quantize_pallas(padded.reshape(nb, QBLOCK), interpret=interpret)
    return q, s, n


def dequantize_vector(q, scales, n, *, interpret: bool = True):
    out = dequantize_pallas(q, scales, interpret=interpret)
    return out.reshape(-1)[:n]
