"""Pure-jnp oracle for the chunkwise mLSTM kernel: delegates to the model's
stabilized parallel form so kernel and model can never drift apart."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.xlstm import mlstm_parallel


def mlstm_ref(q, k, v, i_gate, f_gate):
    """q/k/v: (B,S,nh,dh); gates (B,S,nh) -> (B,S,nh,dh)."""
    return mlstm_parallel(q, k, v, i_gate, f_gate)
