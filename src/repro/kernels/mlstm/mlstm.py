"""Pallas TPU kernel: blockwise (chunkwise-parallel) mLSTM.

The quadratic parallel mLSTM materializes the (S, S) gating matrix
D[t,s] = F_t - F_s + i_s — at 32k context that is the 70 GiB memory wall the
dry-run exposed for xlstm-350m prefill. This kernel runs the same math
flash-attention-style: stream KV/gate blocks, keep a running row-max of D
(the xLSTM stabilizer), rescale the accumulator and normalizer online, and
never materialize more than a (BQ, BK) tile.

    D_blk  = F_q[:,None] - F_k[None,:] + i_k[None,:]   (+ causal mask)
    m'     = max(m, rowmax(D_blk));  c = exp(m - m')
    s      = (q @ k^T / sqrt(dh)) * exp(D_blk - m')
    n      = c*n + rowsum(s)            (signed!)
    acc    = c*acc + s @ v
    out    = acc / max(|n|, exp(-m'))

F = cumsum(log sigmoid(f)) is computed outside (O(S), one pass) and streamed
in per block. Same tiling budget as the flash kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _mlstm_kernel(q_ref, k_ref, v_ref, fq_ref, fk_ref, ik_ref, o_ref,
                  m_ref, n_ref, acc_ref, *, scale: float, bq: int, bk: int,
                  nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        n_ref[...] = jnp.zeros_like(n_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    f_q = fq_ref[0]                                  # (bq,)
    f_k = fk_ref[0]                                  # (bk,)
    i_k = ik_ref[0]                                  # (bk,)

    d = f_q[:, None] - f_k[None, :] + i_k[None, :]   # (bq, bk)
    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    d = jnp.where(k_pos <= q_pos, d, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(d, axis=1))
    corr = jnp.exp(m_prev - m_new)
    gate = jnp.exp(d - m_new[:, None])
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale * gate
    n_ref[...] = corr * n_ref[...] + jnp.sum(s, axis=1)
    acc_ref[...] = corr[:, None] * acc_ref[...] + jax.lax.dot_general(
        s.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(jnp.abs(n_ref[...]), jnp.exp(-m_ref[...]))
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def mlstm_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                 i_gate: jax.Array, f_gate: jax.Array, *,
                 bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                 interpret: bool = True) -> jax.Array:
    """q/k/v: (B,S,nh,dh); i/f gate logits: (B,S,nh) -> (B,S,nh,dh)."""
    B, S, nh, dh = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    # cumulative log-sigmoid forget gates, per (batch*head)
    logf = jax.nn.log_sigmoid(f_gate.astype(jnp.float32))   # (B,S,nh)
    F = jnp.cumsum(logf, axis=1)
    bhs = lambda x: x.transpose(0, 2, 1, 3).reshape(B * nh, S, dh)
    bh2 = lambda x: x.transpose(0, 2, 1).reshape(B * nh, S)
    kernel = functools.partial(_mlstm_kernel, scale=dh ** -0.5, bq=bq,
                               bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * nh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bq), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, bk), lambda b, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * nh, S, dh), v.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(bhs(q), bhs(k), bhs(v), bh2(F),
      bh2(F), bh2(i_gate.astype(jnp.float32)))
    return out.reshape(B, nh, S, dh).transpose(0, 2, 1, 3)
