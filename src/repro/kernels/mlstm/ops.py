"""Jit'd wrapper for the chunkwise mLSTM kernel."""

from __future__ import annotations

from repro.kernels.mlstm.mlstm import mlstm_pallas


def mlstm(q, k, v, i_gate, f_gate, *, interpret: bool = True):
    return mlstm_pallas(q, k, v, i_gate, f_gate, interpret=interpret)
