"""Pallas TPU kernel: fused weighted parameter aggregation (FedAvg / paper
Eq. 1).

The FL server's hot loop is ``out = sum_k w_k * x_k`` over K client vectors of
N params (N up to tens of billions). One pass over HBM: each grid step
streams a (K, BN) tile into VMEM, reduces over K on the VPU, writes (BN,)
back — arithmetic intensity is too low for the MXU, so the win is purely
bandwidth (one fused read instead of K-1 accumulate passes).

Tiling: BN = 16384 floats (64 KiB/client in VMEM; K<=32 keeps the tile under
2 MiB), lane-aligned at 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 16_384


def _fedavg_kernel(w_ref, x_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)          # (K, 1)
    x = x_ref[...].astype(jnp.float32)          # (K, BN)
    o_ref[...] = jnp.sum(w * x, axis=0)         # (BN,)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def fedavg_pallas(stack: jax.Array, weights: jax.Array, *,
                  block_n: int = BLOCK_N, interpret: bool = True
                  ) -> jax.Array:
    """stack (K, N) f32, weights (K,) -> (N,) f32. N padded internally."""
    K, N = stack.shape
    n_pad = (-N) % block_n
    if n_pad:
        stack = jnp.pad(stack, ((0, 0), (0, n_pad)))
    npad = N + n_pad
    grid = (npad // block_n,)
    out = pl.pallas_call(
        _fedavg_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, block_n), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), jnp.float32),
        interpret=interpret,
    )(weights.reshape(K, 1).astype(jnp.float32),
      stack.astype(jnp.float32))
    return out[:N]
