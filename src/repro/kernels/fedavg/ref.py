"""Pure-jnp oracle for the fused FedAvg kernel.

Semantics: ``out = sum_k weights[k] * stack[k]`` over pre-normalized weights.
Paper Eq. (1) is the K=2, w=(0.5, 0.5) case.
"""

from __future__ import annotations

import jax.numpy as jnp


def fedavg_flat(stack: jnp.ndarray, weights: jnp.ndarray) -> jnp.ndarray:
    """stack: (K, N) float32 client vectors; weights: (K,) pre-normalized."""
    return jnp.einsum("k,kn->n", weights.astype(jnp.float32),
                      stack.astype(jnp.float32))
