"""Jit'd public wrapper for the fedavg kernel (+ convenience pytree API)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packetizer import flatten_to_vector, unflatten_from_vector
from repro.kernels.fedavg.fedavg import fedavg_pallas
from repro.kernels.fedavg.ref import fedavg_flat as ref_fedavg_flat


def fedavg_flat(stack, weights, *, interpret: bool = True):
    """Normalized weighted mean over K flat client vectors."""
    w = jnp.asarray(weights, jnp.float32)
    w = w / jnp.sum(w)
    return fedavg_pallas(jnp.asarray(stack, jnp.float32), w,
                         interpret=interpret)


def fedavg_trees(trees, weights, *, interpret: bool = True):
    """Aggregate a list of parameter pytrees (server-side fast path)."""
    stack = jnp.stack([flatten_to_vector(t) for t in trees])
    out = np.asarray(fedavg_flat(stack, weights, interpret=interpret))
    return unflatten_from_vector(out, trees[0])


def pairwise_average_flat(server_vec, client_vec, *, interpret: bool = True):
    """Paper Eq. (1) as the K=2 equal-weight case."""
    stack = jnp.stack([jnp.asarray(server_vec, jnp.float32),
                       jnp.asarray(client_vec, jnp.float32)])
    return fedavg_flat(stack, jnp.asarray([1.0, 1.0]), interpret=interpret)
