"""Pallas TPU kernel: blockwise online-softmax (flash) attention with causal
and sliding-window masking.

Grid (B*H, S/BQ, T/BK); the trailing grid axis is sequential on TPU, so the
running max / sum / accumulator live in VMEM scratch across the KV sweep:

  step ik:  s   = (q @ k^T) * scale  + mask           (BQ, BK) f32 on MXU
            m'  = max(m, rowmax(s));  c = exp(m - m')
            l   = c*l + rowsum(exp(s - m'))
            acc = c*acc + exp(s - m') @ v
  last ik:  out = acc / l

BQ = BK = 128 aligns both MXU operands; hd is the lane dimension (128/256).
VMEM/step: q,k,v tiles + acc = (3*BK*hd + BQ*hd)*4B ~= 256 KiB at hd=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int,
                  bq: int, bk: int, nk: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                    # (bq, hd)
    k = k_ref[0]                                    # (bk, hd)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (q_pos - k_pos < window)
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = corr[:, None] * acc_ref[...] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "bq", "bk", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = True) -> jax.Array:
    """q: (B,H,S,hd); k/v: (B,H,T,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk
    bh = B * H
    qf = q.reshape(bh, S, hd)
    kf = k.reshape(bh, T, hd)
    vf = v.reshape(bh, T, hd)
    kernel = functools.partial(
        _flash_kernel, scale=hd ** -0.5, causal=causal, window=window,
        bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
