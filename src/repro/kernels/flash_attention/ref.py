"""Pure-jnp oracle for the flash attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B,H,S,hd), k/v: (B,H,T,hd) -> (B,H,S,hd). f32 softmax."""
    S, T = q.shape[2], k.shape[2]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    qi = jnp.arange(S)[:, None]
    ki = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (ki <= qi)
    if window > 0:
        ok = ok & (qi - ki < window)
    s = jnp.where(ok[None, None], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(v.dtype)
