"""Jit'd wrapper: GQA-aware flash attention in the model's (B,S,H,hd)
layout."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import \
    flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool = True):
    """q: (B,S,H,hd); k/v: (B,T,KV,hd) -> (B,S,H,hd). KV heads broadcast."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if KV != H:
        G = H // KV
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    out = flash_attention_pallas(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal, window=window,
        interpret=interpret)
    return out.transpose(0, 2, 1, 3)
