"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.float32(lr)


def linear_warmup(lr: float, warmup_steps: int):
    def fn(step):
        frac = jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1),
                           1.0)
        return jnp.float32(lr) * frac
    return fn


def cosine_schedule(lr: float, warmup_steps: int, total_steps: int,
                    final_fraction: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = jnp.minimum(s / max(warmup_steps, 1), 1.0)
        prog = jnp.clip((s - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_fraction + (1 - final_fraction) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.float32(lr) * warm * cos
    return fn
