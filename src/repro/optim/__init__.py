from repro.optim.optimizers import (Adafactor, AdamW, Optimizer, Sgd,
                                    TrainState, make_optimizer)
from repro.optim.schedules import constant, cosine_schedule, linear_warmup

__all__ = ["Adafactor", "AdamW", "Optimizer", "Sgd", "TrainState",
           "make_optimizer", "constant", "cosine_schedule", "linear_warmup"]
