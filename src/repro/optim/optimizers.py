"""Pure-JAX optimizers with sharding-aware state.

AdamW keeps float32 moments regardless of (bf16) param dtype — the standard
mixed-precision recipe; moments inherit the parameter sharding specs so
optimizer state is ZeRO-sharded for free under the FSDP rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    step: jax.Array
    params: Any
    opt_state: Any


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


class Optimizer:
    def init(self, params):  # pragma: no cover - interface
        raise NotImplementedError

    def update(self, grads, state, params, step):  # pragma: no cover
        raise NotImplementedError

    def state_specs(self, param_specs):
        """Logical-axis specs for the optimizer state, mirroring params."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class AdamW(Optimizer):
    schedule: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # Low-precision moments: the standard trick for fitting very large
    # models' optimizer state in HBM (update math stays in float32).
    moments_dtype: str = "float32"
    # Stream the update over the leading (stacked-layer) axis of huge
    # leaves so float32 intermediates never materialize at full leaf size.
    update_chunk_threshold: int = 0   # 0 = off; else leaf bytes that trigger

    def init(self, params):
        dt = jnp.dtype(self.moments_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params)}

    def update(self, grads, state, params, step):
        if self.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        else:
            gnorm = global_norm(grads)
        lr = self.schedule(step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - self.b1 ** t
        bc2 = 1.0 - self.b2 ** t
        mdt = jnp.dtype(self.moments_dtype)

        def upd_math(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m2 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g32
            v2 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g32 * g32
            mhat = m2 / bc1
            vhat = v2 / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                    m2.astype(mdt), v2.astype(mdt))

        def upd(g, m, v, p):
            thresh = self.update_chunk_threshold
            if (thresh and p.ndim >= 3 and p.shape[0] > 4
                    and p.size * 4 > thresh):
                # stream over the stacked-layer axis: f32 temps are 1/L-sized
                return jax.lax.map(lambda a: upd_math(*a), (g, m, v, p))
            return upd_math(g, m, v, p)

        flat = jax.tree_util.tree_map(upd, grads, state["m"], state["v"],
                                      params)
        new_params = jax.tree_util.tree_map(lambda x: x[0], flat,
                                            is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree_util.tree_map(lambda x: x[1], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree_util.tree_map(lambda x: x[2], flat,
                                       is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, {
            "grad_norm": gnorm, "lr": lr}

    def state_specs(self, param_specs):
        return {"m": param_specs, "v": param_specs}


@dataclasses.dataclass(frozen=True)
class Adafactor(Optimizer):
    """Factored second-moment optimizer (Shazeer & Stern 2018) — the
    standard choice when a model's Adam state cannot fit HBM: v is stored as
    per-row/per-column running means (O(rows+cols) instead of O(rows*cols)),
    first moment omitted. State for a 235B model: ~params-size/4096."""

    schedule: Callable[[jax.Array], jax.Array]
    decay: float = 0.8          # \hat{beta2}_t = 1 - t^-decay
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0
    grad_clip: float = 1.0

    def init(self, params):
        def leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree_util.tree_map(
            leaf, params, is_leaf=lambda x: hasattr(x, "ndim"))}

    def update(self, grads, state, params, step):
        if self.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        else:
            gnorm = global_norm(grads)
        lr = self.schedule(step)
        t = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - t ** (-self.decay)

        def upd(g, st, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps
            if p.ndim >= 2:
                vr = beta2 * st["vr"] + (1 - beta2) * g2.mean(axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * g2.mean(axis=-2)
                denom = (vr[..., None] / jnp.maximum(
                    vr.mean(axis=-1, keepdims=True), self.eps)[..., None]) \
                    * vc[..., None, :]
                u = g32 * jax.lax.rsqrt(denom + self.eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                u = g32 * jax.lax.rsqrt(v + self.eps)
                new_st = {"v": v}
            # update clipping by RMS (Adafactor's stabilizer)
            rms_u = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / self.clip_threshold)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_st

        is_st = lambda x: isinstance(x, dict) and ("vr" in x or "v" in x)
        flat = jax.tree_util.tree_map(
            upd, grads, state["f"], params,
            is_leaf=lambda x: is_st(x))
        tup = lambda x: isinstance(x, tuple)
        new_params = jax.tree_util.tree_map(lambda x: x[0], flat, is_leaf=tup)
        new_f = jax.tree_util.tree_map(lambda x: x[1], flat, is_leaf=tup)
        return new_params, {"f": new_f}, {"grad_norm": gnorm, "lr": lr}

    def state_specs(self, param_specs):
        def leaf(spec):
            if len(spec) >= 2:
                return {"vr": spec[:-1], "vc": spec[:-2] + spec[-1:]}
            return {"v": spec}
        from repro.distributed.sharding import _is_spec_leaf
        return {"f": jax.tree_util.tree_map(leaf, param_specs,
                                            is_leaf=_is_spec_leaf)}


@dataclasses.dataclass(frozen=True)
class Sgd(Optimizer):
    schedule: Callable[[jax.Array], jax.Array]
    momentum: float = 0.0
    grad_clip: float = 0.0

    def init(self, params):
        if self.momentum == 0.0:
            return {}
        return {"mom": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(self, grads, state, params, step):
        if self.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, self.grad_clip)
        else:
            gnorm = global_norm(grads)
        lr = self.schedule(step)
        if self.momentum == 0.0:
            new_params = jax.tree_util.tree_map(
                lambda p, g: (p.astype(jnp.float32)
                              - lr * g.astype(jnp.float32)).astype(p.dtype),
                params, grads)
            return new_params, {}, {"grad_norm": gnorm, "lr": lr}
        new_mom = jax.tree_util.tree_map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state["mom"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, new_mom)
        return new_params, {"mom": new_mom}, {"grad_norm": gnorm, "lr": lr}

    def state_specs(self, param_specs):
        return {} if self.momentum == 0.0 else {"mom": param_specs}


def make_optimizer(name: str, schedule, **kw) -> Optimizer:
    if name == "adamw":
        return AdamW(schedule=schedule, **kw)
    if name == "adafactor":
        kw.pop("moments_dtype", None)
        return Adafactor(schedule=schedule, **kw)
    if name == "sgd":
        return Sgd(schedule=schedule, **kw)
    raise ValueError(f"unknown optimizer {name}")
