"""granite-34b [dense] — 88L d_model=6144 48H (GQA kv=1 / MQA) d_ff=24576
vocab=49152. Code model, gpt_bigcode-lineage ("llama-arch" per pool listing).
[arXiv:2405.04324; hf]

Assumption recorded (DESIGN.md): MQA (kv=1) and 4x gelu MLP match the
published gpt_bigcode config; we pair them with RoPE as the pool entry labels
it llama-arch. Shape-defining fields are exact.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    source="arXiv:2405.04324; hf",
))
