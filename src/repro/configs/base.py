"""Config system: model architecture, input shapes, training, FL.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``
and registers itself; ``get_config(name)`` / ``--arch <id>`` resolve from the
registry. Shape presets (train_4k / prefill_32k / decode_32k / long_500k) are
``ShapeConfig`` objects paired with the entry point they lower.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | encdec | vlm | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    mlp_type: str = "swiglu"       # swiglu | gelu
    # -- attention pattern -------------------------------------------------
    sliding_window: int = 0        # 0 = full attention
    global_every: int = 0          # gemma3: 1 global layer per N (5 local : 1)
    full_attn_layers: tuple = ()   # hymba: explicit full-attention layer ids
    rope_theta: float = 10_000.0
    # -- MoE ----------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    # -- enc-dec (whisper) ---------------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0           # fixed frame count from the audio frontend
    # -- SSM / hybrid ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    slstm_every: int = 0           # xLSTM: 1 sLSTM block per N (7 mLSTM : 1)
    # -- VLM -------------------------------------------------------------------
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    vision_tokens: int = 0         # patch-embedding prefix length (stub)
    # -- numerics ---------------------------------------------------------------
    dtype: str = "bfloat16"
    tie_embeddings: bool = False
    # -- capability flags ---------------------------------------------------------
    subquadratic: bool = False     # may run long_500k
    has_decoder: bool = True       # encoder-only archs skip decode shapes
    source: str = ""               # provenance note

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_groups(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding tables are padded so the 'model' mesh axis always
        divides the vocab (the MaxText convention)."""
        return -(-self.vocab_size // 256) * 256

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping).
        Matches what init() allocates (asserted in tests)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        att = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
            + self.num_heads * hd * d
        if self.family in ("ssm",):
            att = 0
        if self.mlp_type == "swiglu":
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.num_experts:
            mlp = self.num_experts * 3 * d * self.d_ff + d * self.num_experts
        per_layer = att + mlp + 2 * d
        total = emb + self.num_layers * per_layer
        if self.family == "encdec":
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            total += self.encoder_layers * per_layer
            total += self.num_layers * (d * self.num_heads * hd
                                        + 2 * d * self.num_kv_heads * hd
                                        + self.num_heads * hd * d)
        if self.family == "ssm":
            # mLSTM: w_up+w_z (2*2d^2) + q/k/v (3*(2d*2d)) + w_down (2d^2)
            # sLSTM: w_gates (4d^2) + r_gates (4d^2/nh) + w_down (d^2)
            n_s = self.num_layers // max(self.slstm_every, 1)
            n_m = self.num_layers - n_s
            total = emb + n_m * 18 * d * d \
                + n_s * (5 * d * d + 4 * d * d // self.num_heads)
        if self.family == "hybrid":
            # SSM path: w_in + w_gate_ssm + w_out_ssm (3d^2) + dt proj (d^2)
            # + B/C/A (3*d*n) + fuse norms
            n = self.ssm_state
            total += self.num_layers * (4 * d * d + 3 * d * n + 2 * d)
        return total

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only top-k experts count)."""
        if not self.num_experts:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * (
            self.num_experts * 3 * d * self.d_ff)
        return dense + self.num_layers * (
            self.num_experts_per_tok * 3 * d * self.d_ff)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str              # train | prefill | decode
    kv_len: int = 0        # decode: populated cache length (== seq_len)


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode",
                              kv_len=32_768),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode",
                             kv_len=524_288),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    optimizer: str = "adamw"
    remat_policy: str = "full"     # none | full | dots
    loss_chunk: int = 0            # 0 = unchunked; >0 = vocab-loss seq chunking
    grad_accum: int = 1            # microbatches per step (memory / step)
    accum_dtype: str = "float32"   # grad-accumulation buffer dtype
    moments_dtype: str = "float32"  # Adam m/v dtype (bf16 for huge models)
    moe_impl: str = "scan"         # scan (baseline) | ragged (dropless)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # Import side-effect registration.
        import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers, narrow
    width, tiny vocab/experts — preserves every structural feature."""
    updates: dict = dict(
        num_layers=max(2, (cfg.slstm_every or cfg.global_every or 2)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads * 4 // cfg.num_heads)),
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=128,
        dtype="float32",
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        encoder_seq=min(cfg.encoder_seq, 24) if cfg.encoder_seq else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        num_experts=min(cfg.num_experts, 8),
        num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
        vision_tokens=min(cfg.vision_tokens, 8),
        full_attn_layers=tuple(
            i for i in (0, 1) if cfg.full_attn_layers) or cfg.full_attn_layers,
    )
    if cfg.global_every:
        updates["num_layers"] = 2 * cfg.global_every
    if cfg.slstm_every:
        updates["num_layers"] = 2 * cfg.slstm_every
    if cfg.mrope:
        # rescale the per-channel frequency sections to the smoke head_dim
        half = updates["head_dim"] // 2
        base = cfg.mrope_sections
        scale = half / sum(base)
        secs = [max(1, int(s * scale)) for s in base]
        secs[0] += half - sum(secs)
        updates["mrope_sections"] = tuple(secs)
    return dataclasses.replace(cfg, **updates)
