"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) expert
d_ff=1536 vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,                 # per-expert FFN width
    vocab_size=151936,
    mlp_type="swiglu",
    num_experts=128,
    num_experts_per_tok=8,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
))
