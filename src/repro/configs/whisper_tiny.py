"""whisper-tiny [audio] — enc-dec, 4L (each side) d_model=384 6H (kv=6 MHA)
d_ff=1536 vocab=51865, conv audio frontend STUBBED (input_specs() provides
precomputed 1500-frame embeddings). [arXiv:2212.04356; unverified]

decode_32k exceeds the model's trained 448-token horizon but is mechanically
supported; long_500k is skipped (full attention).
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,              # decoder layers
    encoder_layers=4,
    encoder_seq=1500,          # 30 s of audio at 20 ms hop after conv stub
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_type="gelu",
    source="arXiv:2212.04356; unverified",
))
