"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
Llama-arch GQA with SwiGLU. [arXiv:2403.04652; hf]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_type="swiglu",
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652; hf",
))
