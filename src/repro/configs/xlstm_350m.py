"""xlstm-350m [ssm] — 24L d_model=1024 4H d_ff=0 vocab=50304, sLSTM + mLSTM
blocks at the paper's 7:1 ratio. [arXiv:2405.04517; unverified]

Attention-free: the technique-bearing transport layer is unaffected (it ships
parameter bytes); ``subquadratic=True`` so long_500k runs with O(1)/token
recurrent state. d_ff=0: xLSTM blocks carry their own up/down projections
instead of a separate FFN.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,
    vocab_size=50304,
    slstm_every=8,             # 7 mLSTM : 1 sLSTM
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2405.04517; unverified",
))
