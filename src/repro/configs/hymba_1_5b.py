"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads per block.
[arXiv:2411.13676; hf]

Sliding-window (1024) attention everywhere except layers {0, mid, last},
which are full attention (the published layout); meta-token prefix is
omitted (stub noted in DESIGN.md). ``subquadratic=True``: decode state is
SWA KV (<=1024) + SSM state.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    mlp_type="swiglu",
    sliding_window=1024,
    full_attn_layers=(0, 15, 31),
    ssm_state=16,
    tie_embeddings=True,
    subquadratic=True,
    source="arXiv:2411.13676; hf",
))
