"""Architecture registry: one module per assigned arch (import = register)."""

from repro.configs.base import (ModelConfig, ShapeConfig, TrainConfig, SHAPES,
                                get_config, list_configs, register,
                                smoke_variant)

# Import side effects populate the registry.
from repro.configs import (granite_34b, starcoder2_7b, yi_9b, gemma3_12b,
                           whisper_tiny, qwen3_moe_235b_a22b, olmoe_1b_7b,
                           qwen2_vl_72b, xlstm_350m, hymba_1_5b)  # noqa: F401

ARCH_IDS = [
    "granite-34b", "starcoder2-7b", "yi-9b", "gemma3-12b", "whisper-tiny",
    "qwen3-moe-235b-a22b", "olmoe-1b-7b", "qwen2-vl-72b", "xlstm-350m",
    "hymba-1.5b",
]

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "SHAPES",
           "get_config", "list_configs", "register", "smoke_variant",
           "ARCH_IDS"]
