"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (MHA kv=16) expert d_ff=1024
vocab=50304, MoE 64 experts top-8. [arXiv:2409.02060; hf]"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    mlp_type="swiglu",
    num_experts=64,
    num_experts_per_tok=8,
    source="arXiv:2409.02060; hf",
))
