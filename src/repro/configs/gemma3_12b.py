"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144. 5:1 local(window=1024):global attention interleave, 128k
context. [hf:google/gemma-3-1b-pt; unverified]

``subquadratic=True``: 40/48 layers are windowed; the 8 global layers' 500k
KV cache is sharded over the data axis with the shard_map LSE-combine decode
(see DESIGN.md §Arch-applicability) — included as the long-context stress
case.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    mlp_type="swiglu",
    sliding_window=1024,
    global_every=6,            # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    subquadratic=True,
    source="hf:google/gemma-3-1b-pt; unverified",
))
