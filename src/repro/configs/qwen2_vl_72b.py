"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE + dynamic resolution. [arXiv:2409.12191; hf]

Vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings merged as a prefix; M-RoPE consumes 3-channel
(temporal, height, width) position ids, also provided by ``input_specs()``.
"""

from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    mlp_type="swiglu",
    mrope=True,
    mrope_sections=(16, 24, 24),
    vision_tokens=64,
    rope_theta=1_000_000.0,
    source="arXiv:2409.12191; hf",
))
