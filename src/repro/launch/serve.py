"""Serving driver: batched prefill + greedy decode with the per-family cache.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --smoke \
      --batch 2 --prompt-len 16 --gen 8
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.models import model as M


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="xlstm-350m", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_variant(cfg)
    if not cfg.has_decoder:
        raise SystemExit(f"{args.arch} has no decode step")
    rng = jax.random.PRNGKey(args.seed)
    params = M.init(cfg, rng)
    B, P, G = args.batch, args.prompt_len, args.gen
    prompt = jax.random.randint(rng, (B, P), 0, cfg.vocab_size)

    decode = jax.jit(M.make_decode_step(cfg))
    max_len = P + G
    if cfg.family == "encdec":
        frames = jax.random.normal(rng, (B, cfg.encoder_seq, cfg.d_model),
                                   jnp.float32)
        lg, cache = M.make_prefill_step(cfg, attn_impl="einsum")(
            params, {"tokens": prompt, "frames": frames})
        pad = max_len - cache["k"].shape[2]
        cache = dict(cache,
                     k=jnp.pad(cache["k"], ((0, 0), (0, 0), (0, pad),
                                            (0, 0), (0, 0))),
                     v=jnp.pad(cache["v"], ((0, 0), (0, 0), (0, pad),
                                            (0, 0), (0, 0))))
        next_tok = jnp.argmax(lg, axis=-1)[:, None]
    else:
        # feed the prompt through decode steps against a full-size cache
        cache = M.init_cache(cfg, B, max_len)
        next_tok = prompt[:, :1]
        for t in range(P):
            lg, cache = decode(params, cache, prompt[:, t:t + 1])
        next_tok = jnp.argmax(lg, axis=-1)[:, None]

    out = [next_tok]
    t0 = time.time()
    for _ in range(G - 1):
        lg, cache = decode(params, cache, next_tok)
        next_tok = jnp.argmax(lg, axis=-1)[:, None]
        out.append(next_tok)
    dt = time.time() - t0
    gen = np.asarray(jnp.concatenate(out, axis=1))
    print(f"arch={cfg.name} generated {gen.shape} tokens "
          f"({(G-1)*B/max(dt,1e-9):.1f} tok/s on this host)")
    for b in range(B):
        print(f"  seq{b}: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
