"""Cell lowering: (architecture x input-shape x mesh) -> compiled artifact +
roofline terms. Shared by the dry-run CLI, the roofline benchmark, and the
perf-iteration harness.

Per cell this produces:
  * lowered + compiled XLA executable (SPMD; the per-device program),
  * memory_analysis (bytes/device — proves the cell fits in HBM),
  * loop-aware HLO costs (FLOPs / bytes / collective bytes, from
    repro.launch.hlo_cost — the raw cost_analysis() undercounts scans),
  * the three roofline terms in seconds and the dominant bottleneck,
  * MODEL_FLOPS = 6·N(_active)·D and the usefulness ratio.

TPU v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Optional

import jax
import numpy as np

from repro.configs import SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed import sharding as sh
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes
from repro.models import model as M
from repro.optim import AdamW, cosine_schedule, make_optimizer

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

# Cells skipped per DESIGN.md §Arch-applicability.
LONG_CONTEXT_OK = {"xlstm-350m", "hymba-1.5b", "gemma3-12b"}

# Per-cell training overrides: >=70B-class models need bf16 optimizer
# moments + bf16 grad accumulation to fit v5e's 16 GiB (recorded in
# EXPERIMENTS.md §Dry-run; numerically standard at this scale).
CELL_TRAIN_OVERRIDES: dict[str, dict] = {
    "qwen3-moe-235b-a22b": dict(optimizer="adafactor",
                                accum_dtype="bfloat16",
                                moe_impl="ragged"),
    "qwen2-vl-72b": dict(moments_dtype="bfloat16",
                         accum_dtype="bfloat16"),
    "granite-34b": dict(moments_dtype="bfloat16"),
}

# Per-cell sharding-rule overrides (applied when the caller passes none):
# sequence-parallel activations for the models whose layer-scan carry stack
# (L x B x S x d) would not fit HBM otherwise (Megatron-SP; DESIGN.md §6).
CELL_RULES_OVERRIDES: dict[tuple[str, str], dict] = {
    ("granite-34b", "train_4k"): {"act_seq": "model"},
    ("qwen2-vl-72b", "train_4k"): {"act_seq": "model"},
    ("qwen3-moe-235b-a22b", "train_4k"): {"act_seq": "model"},
    # Serve-time FSDP: >=34B-class weights cannot replicate across the data
    # axis on 16 GiB chips — keep the 2D weight sharding at inference.
    ("granite-34b", "prefill_32k"): {"w_data": "data", "embed_d": "data"},
    ("qwen2-vl-72b", "prefill_32k"): {"w_data": "data", "embed_d": "data"},
    ("qwen2-vl-72b", "decode_32k"): {"w_data": "data", "embed_d": "data"},
    ("qwen3-moe-235b-a22b", "prefill_32k"): {"w_data": "data",
                                             "embed_d": "data"},
    ("qwen3-moe-235b-a22b", "decode_32k"): {"w_data": "data",
                                            "embed_d": "data"},
}


def xla_cost_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized to one flat dict: older jax
    returns a one-element list of per-device dicts, newer jax the dict
    itself, and either may be None for unsupported backends."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def cell_is_skipped(arch: str, shape_name: str) -> Optional[str]:
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_OK:
        return ("pure full-attention arch: 500k decode cache excluded "
                "(DESIGN.md §Arch-applicability)")
    return None


@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    status: str = "ok"
    error: str = ""
    # memory_analysis
    bytes_per_device: float = 0.0
    argument_bytes: float = 0.0
    temp_bytes: float = 0.0
    output_bytes: float = 0.0
    # loop-aware HLO costs (per device)
    hlo_flops: float = 0.0
    hlo_bytes: float = 0.0          # raw per-instruction I/O (upper bound)
    hlo_bytes_fused: float = 0.0    # TPU-fused traffic model (memory term)
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    xla_flops_raw: float = 0.0     # uncorrected cost_analysis() for reference
    # roofline
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops_global: float = 0.0
    useful_ratio: float = 0.0
    compile_seconds: float = 0.0
    num_devices: int = 0
    notes: str = ""

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful model FLOPs for this entry point (6ND convention)."""
    n = cfg.active_param_count()
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token


def _build_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                     *, attn_impl: Optional[str], train_cfg: TrainConfig):
    """Returns (jitted_fn, example_args) under the active mesh+rules."""
    ins = M.input_specs(cfg, shape)
    if shape.mode == "train":
        if train_cfg.grad_accum == 0:  # auto: ~4k tokens per device per micro
            sizes = mesh_axis_sizes(mesh)
            ways = 1
            t = rules.get("batch")
            for nm in (t if isinstance(t, tuple) else (t,)):
                ways *= sizes.get(nm, 1) if nm else 1
            b_loc = max(1, shape.global_batch // ways)
            accum = max(1, min(b_loc, b_loc * shape.seq_len // 4096))
            train_cfg = dataclasses.replace(train_cfg, grad_accum=accum)
        opt = make_optimizer(
            train_cfg.optimizer,
            cosine_schedule(train_cfg.learning_rate, train_cfg.warmup_steps,
                            train_cfg.total_steps),
            weight_decay=train_cfg.weight_decay,
            grad_clip=train_cfg.grad_clip,
            moments_dtype=train_cfg.moments_dtype)
        state = M.abstract_train_state(cfg, opt)
        st_shard = sh.tree_shardings(M.train_state_specs(cfg, opt))
        b_shard = sh.tree_shardings(M.batch_specs(cfg, shape))["batch"]
        step = M.make_train_step(cfg, opt, train_cfg,
                                 attn_impl=attn_impl or "einsum")
        fn = jax.jit(step, in_shardings=(st_shard, b_shard),
                     donate_argnums=(0,))
        return fn, (state, ins["batch"])
    params = M.abstract_params(cfg)
    p_shard = sh.tree_shardings(M.param_specs(cfg))
    if shape.mode == "prefill":
        b_shard = sh.tree_shardings(M.batch_specs(cfg, shape))["batch"]
        cache_shard = sh.tree_shardings(M.cache_specs(cfg))
        prefill = M.make_prefill_step(cfg, attn_impl=attn_impl or "chunked")
        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard),
                     out_shardings=(None, cache_shard))
        return fn, (params, ins["batch"])
    # decode
    spec = sh.tree_shardings(M.batch_specs(cfg, shape))
    decode = M.make_decode_step(cfg)
    if cfg.mrope:
        fn = jax.jit(lambda p, c, t, pos: decode(p, c, t, pos),
                     in_shardings=(p_shard, spec["cache"], spec["tokens"],
                                   spec["positions"]),
                     out_shardings=(None, spec["cache"]),
                     donate_argnums=(1,))
        return fn, (params, ins["cache"], ins["tokens"], ins["positions"])
    fn = jax.jit(lambda p, c, t: decode(p, c, t),
                 in_shardings=(p_shard, spec["cache"], spec["tokens"]),
                 out_shardings=(None, spec["cache"]),
                 donate_argnums=(1,))
    return fn, (params, ins["cache"], ins["tokens"])


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               attn_impl: Optional[str] = None,
               train_cfg: Optional[TrainConfig] = None,
               rules_override: Optional[dict] = None,
               mesh=None, keep_artifacts: bool = False,
               notes: str = "") -> CellReport:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rep = CellReport(arch=arch, shape=shape_name, mesh=mesh_name, notes=notes)

    skip = cell_is_skipped(arch, shape_name)
    if skip:
        rep.status, rep.error = "skipped", skip
        return rep

    t0 = time.time()
    try:
        mesh = mesh if mesh is not None else \
            make_production_mesh(multi_pod=multi_pod)
        rep.num_devices = int(np.prod(mesh.devices.shape))
        rules = sh.rules_for(cfg, shape, mesh)
        if rules_override is None:
            rules_override = CELL_RULES_OVERRIDES.get((arch, shape_name))
        if rules_override:
            rules.update(rules_override)
            rep.notes = (rep.notes + " " if rep.notes else "") + \
                f"rules overrides: {rules_override}"
        if train_cfg is None:
            over = CELL_TRAIN_OVERRIDES.get(arch, {})
            train_cfg = TrainConfig(grad_accum=0, **over)
            if over:
                rep.notes = (rep.notes + " " if rep.notes else "") + \
                    f"train overrides: {over}"
        with sh.use_mesh(mesh, rules):
            fn, args = _build_lowerable(
                cfg, shape, mesh, rules, attn_impl=attn_impl,
                train_cfg=train_cfg)
            lowered = fn.lower(*args)
            compiled = lowered.compile()
        rep.compile_seconds = time.time() - t0

        mem = compiled.memory_analysis()
        rep.argument_bytes = float(mem.argument_size_in_bytes)
        rep.temp_bytes = float(mem.temp_size_in_bytes)
        rep.output_bytes = float(mem.output_size_in_bytes)
        rep.bytes_per_device = float(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)

        ca = xla_cost_dict(compiled)
        rep.xla_flops_raw = float(ca.get("flops", 0.0))

        cost = hlo_cost.analyze_hlo_text(compiled.as_text())
        rep.hlo_flops = cost.flops
        rep.hlo_bytes = cost.bytes_accessed
        rep.hlo_bytes_fused = cost.bytes_fused
        rep.collective_bytes = cost.collective_bytes
        rep.collective_counts = dict(cost.collective_counts)

        rep.compute_s = cost.flops / PEAK_FLOPS
        rep.memory_s = cost.bytes_fused / HBM_BW
        rep.collective_s = cost.collective_bytes / ICI_BW
        terms = {"compute": rep.compute_s, "memory": rep.memory_s,
                 "collective": rep.collective_s}
        rep.dominant = max(terms, key=terms.get)
        rep.model_flops_global = model_flops(cfg, shape)
        total_hlo = cost.flops * rep.num_devices
        rep.useful_ratio = (rep.model_flops_global / total_hlo
                            if total_hlo else 0.0)
        if keep_artifacts:
            rep.lowered = lowered            # type: ignore[attr-defined]
            rep.compiled = compiled          # type: ignore[attr-defined]
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        rep.status = "error"
        rep.error = f"{type(e).__name__}: {e}"[:2000]
        rep.compile_seconds = time.time() - t0
    return rep


def shape_applicable(cfg: ModelConfig, shape_name: str) -> bool:
    if not cfg.has_decoder and SHAPES[shape_name].mode == "decode":
        return False
    return True
