import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory/cost/collective analysis.

The two lines above MUST stay the first statements in this module — jax locks
the device count on first init, and the dry-run needs 512 placeholder host
devices to build the (2, 16, 16) multi-pod mesh. Everything else (tests,
benches) sees 1 CPU device because only this entry point sets the flag.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.json
"""

import argparse
import json
import sys

import jax  # noqa: E402  (intentionally after XLA_FLAGS)

from repro.configs import ARCH_IDS, SHAPES, get_config  # noqa: E402
from repro.launch.lowering import (cell_is_skipped, lower_cell,  # noqa: E402
                                   shape_applicable)


def run_cells(archs, shapes, meshes, *, attn_impl=None, out_path=None,
              verbose=True):
    reports = []
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if not shape_applicable(cfg, shape_name):
                continue
            for multi_pod in meshes:
                rep = lower_cell(arch, shape_name, multi_pod=multi_pod,
                                 attn_impl=attn_impl)
                reports.append(rep)
                if verbose:
                    mark = {"ok": "PASS", "skipped": "SKIP",
                            "error": "FAIL"}[rep.status]
                    line = (f"[{mark}] {arch:22s} {shape_name:12s} "
                            f"{rep.mesh:10s}")
                    if rep.status == "ok":
                        line += (f" mem/dev={rep.bytes_per_device/2**30:7.2f}GiB"
                                 f" flops/dev={rep.hlo_flops:.3e}"
                                 f" coll/dev={rep.collective_bytes:.3e}B"
                                 f" dominant={rep.dominant}"
                                 f" compile={rep.compile_seconds:.0f}s")
                    else:
                        line += f" {rep.error[:120]}"
                    print(line, flush=True)
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump([r.to_json() for r in reports], f, indent=1)
    return reports


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", default=None,
                    help="architecture id (repeatable); default: all")
    ap.add_argument("--shape", action="append", default=None,
                    choices=list(SHAPES), help="shape preset (repeatable)")
    ap.add_argument("--all", action="store_true",
                    help="all archs x all shapes")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="both")
    ap.add_argument("--attn-impl", choices=["einsum", "chunked"],
                    default=None)
    ap.add_argument("--out", default=None, help="JSON report path")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run requires the 512 fake host devices (XLA_FLAGS not applied "
        "— was jax initialized before this module?)")

    archs = args.arch or ARCH_IDS
    shapes = args.shape or list(SHAPES)
    meshes = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]
    reports = run_cells(archs, shapes, meshes, attn_impl=args.attn_impl,
                        out_path=args.out)
    bad = [r for r in reports if r.status == "error"]
    print(f"\n{len(reports)} cells: "
          f"{sum(r.status == 'ok' for r in reports)} ok, "
          f"{sum(r.status == 'skipped' for r in reports)} skipped, "
          f"{len(bad)} failed")
    for r in bad:
        print(f"  FAIL {r.arch} {r.shape} {r.mesh}: {r.error[:200]}")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
