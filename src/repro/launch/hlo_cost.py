"""Loop-aware cost analysis over compiled HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body ONCE —
under scan-over-layers every per-layer dot/collective would be undercounted
by the layer count (verified empirically in tests). This module re-derives

  * FLOPs           (dot ops: 2 * prod(result) * prod(lhs contracting dims)),
  * bytes accessed  (per instruction: operands + result, fusion-boundary
                     semantics like HloCostAnalysis; tuple/GTE/bitcast free),
  * collective bytes (all-gather/all-reduce/reduce-scatter/all-to-all/
                      collective-permute, with ring-cost multipliers)

by walking the computation graph and multiplying ``while`` bodies by their
``known_trip_count`` (XLA annotates scans with it; unknowable loops count
once and are reported).

This is text parsing of the stable HLO dump format — deliberately defensive:
anything unparseable contributes zero and is tallied in ``warnings``.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0,
    "opaque": 0,
}

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

COLLECTIVE_MULTIPLIER = {
    # bytes moved per device ~ multiplier * buffer bytes (ring algorithms;
    # (k-1)/k ~ 1 omitted, documented in EXPERIMENTS.md)
    "all-reduce": 2.0, "all-reduce-start": 2.0,
    "all-gather": 1.0, "all-gather-start": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0, "collective-permute-start": 1.0,
}


def _type_bytes(type_str: str) -> int:
    """Total bytes of an HLO type string (array or tuple)."""
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _array_dims(type_str: str) -> Optional[list[int]]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    attrs: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: list[_Instr]
    param_types: dict[str, str]


_COMP_HEADER = re.compile(
    r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s+->\s+.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*)$")
_OPERAND_NAME = re.compile(r"%([\w\.\-]+)")


def _split_type_and_rest(rest: str) -> tuple[str, str]:
    """rest = '<type> <op>(<operands>), attrs...' -> (type_str, remainder)."""
    rest = rest.strip()
    if rest.startswith("("):
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return rest[:i + 1], rest[i + 1:].strip()
        return rest, ""
    sp = rest.find(" ")
    return (rest, "") if sp < 0 else (rest[:sp], rest[sp + 1:].strip())


def _parse_params(sig: str) -> dict[str, str]:
    """'a.1: bf16[4], b: (s32[], f32[2,2])' -> {name: type_str}"""
    out = {}
    depth = 0
    start = 0
    parts = []
    for i, ch in enumerate(sig):
        if ch in "([":
            depth += 1
        elif ch in ")]":
            depth -= 1
        elif ch == "," and depth == 0:
            parts.append(sig[start:i])
            start = i + 1
    if sig[start:].strip():
        parts.append(sig[start:])
    for p in parts:
        if ":" in p:
            name, t = p.split(":", 1)
            out[name.strip().lstrip("%")] = t.strip()
    return out


def parse_hlo(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = _Computation(m.group(1), [], _parse_params(m.group(2)))
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        type_str, remainder = _split_type_and_rest(rest)
        om = re.match(r"([\w\-]+)\(", remainder)
        if not om:
            continue
        op = om.group(1)
        # operand segment: balanced parens after op name
        depth = 0
        opstart = remainder.find("(")
        opend = opstart
        for i in range(opstart, len(remainder)):
            if remainder[i] == "(":
                depth += 1
            elif remainder[i] == ")":
                depth -= 1
                if depth == 0:
                    opend = i
                    break
        operand_str = remainder[opstart + 1:opend]
        attrs = remainder[opend + 1:]
        operands = _OPERAND_NAME.findall(operand_str)
        cur.instrs.append(_Instr(name, type_str, op, operands, attrs))
    return comps


_FREE_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter", "constant",
             "after-all", "partition-id", "replica-id", "iota",
             "all-gather-done", "all-reduce-done", "collective-permute-done",
             "copy-done", "copy-start", "opt-barrier"}

_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w\.\-]+)")
_COND_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    # TPU-fused traffic model: XLA:TPU fuses elementwise/convert/broadcast
    # chains into their producers/consumers, so only data-moving ops (dots,
    # copies, DUS, gathers/scatters, sorts, fusion boundaries, collectives,
    # loop-carried state) touch HBM. XLA:CPU leaves those chains unfused in
    # the HLO, so ``bytes_accessed`` (HloCostAnalysis semantics) overcounts
    # them; ``bytes_fused`` is the roofline's memory term.
    bytes_fused: float = 0.0
    collective_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0
    warnings: list = dataclasses.field(default_factory=list)

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes_accessed * k,
                       self.bytes_fused * k,
                       self.collective_bytes * k,
                       {n: c * k for n, c in self.collective_counts.items()},
                       self.unknown_trip_loops, list(self.warnings))

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes_accessed += other.bytes_accessed
        self.bytes_fused += other.bytes_fused
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v
        self.unknown_trip_loops += other.unknown_trip_loops
        self.warnings.extend(other.warnings)


# Ops whose I/O hits HBM even under TPU fusion.
_TRAFFIC_OPS = {
    "dot", "dot-general", "convolution", "fusion", "call", "custom-call",
    "copy", "dynamic-update-slice", "dynamic-slice", "gather", "scatter",
    "sort", "map", "reduce", "reduce-window", "select-and-scatter",
    "concatenate", "pad", "slice", "transpose",
}

# Pure-elementwise ops: a fusion whose body contains ONLY these would be
# folded into its producers/consumers by XLA:TPU — its I/O is not real HBM
# traffic. XLA:CPU emits them as single-op kLoop fusions.
_EW_OPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "exponential-minus-one", "convert", "select", "compare",
    "broadcast", "negate", "rsqrt", "sqrt", "tanh", "logistic", "log",
    "log-plus-one", "abs", "sign", "and", "or", "not", "xor", "floor",
    "ceil", "round-nearest-even", "round-nearest-afz", "clamp", "power",
    "parameter", "constant", "iota", "reshape", "bitcast", "tuple",
    "get-tuple-element", "is-finite", "reduce", "rem", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "popcnt", "clz",
    "atan2", "cbrt", "cosine", "sine", "erf", "expm1", "log1p",
}


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        self._memo: dict[str, HloCost] = {}
        self._ew_memo: dict[str, bool] = {}
        self._entry = None
        for line in text.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HEADER.match(line.strip())
                if m:
                    self._entry = m.group(1)
        if self._entry is None:  # fall back: computation named main*
            for name in self.comps:
                if name.startswith("main"):
                    self._entry = name

    # -- per-computation symbol table --------------------------------------
    def _shapes(self, comp: _Computation) -> dict[str, str]:
        table = dict(comp.param_types)
        for ins in comp.instrs:
            table[ins.name] = ins.type_str
        return table

    def _dot_flops(self, ins: _Instr, table: dict[str, str]) -> float:
        dims = _array_dims(ins.type_str)
        if dims is None:
            return 0.0
        result_elems = 1
        for d in dims:
            result_elems *= d
        contract = 1
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
        if m and ins.operands:
            lhs_type = table.get(ins.operands[0])
            lhs_dims = _array_dims(lhs_type) if lhs_type else None
            if lhs_dims is not None and m.group(1):
                for idx in m.group(1).split(","):
                    i = int(idx)
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
        return 2.0 * result_elems * contract

    def cost_of(self, comp_name: str) -> HloCost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        out = HloCost()
        if comp is None:
            out.warnings.append(f"missing computation {comp_name}")
            self._memo[comp_name] = out
            return out
        self._memo[comp_name] = out  # break cycles defensively
        table = self._shapes(comp)
        for ins in comp.instrs:
            if ins.op == "while":
                m = _TRIP_RE.search(ins.attrs)
                trip = int(m.group(1)) if m else 1
                if not m:
                    out.unknown_trip_loops += 1
                bm = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
                if bm:
                    out.add(self.cost_of(bm.group(1)).scaled(trip))
                continue
            if ins.op in ("fusion", "call", "custom-call", "map", "reduce",
                          "reduce-window", "sort", "scatter", "select-and-scatter"):
                # bytes at the boundary
                io = self._io_bytes(ins, table)
                out.bytes_accessed += io
                if not (ins.op == "fusion"
                        and self._fusion_is_elementwise(ins)):
                    out.bytes_fused += io
                # flops inside called computations (dots can hide in there)
                for target in _CALLS_RE.findall(ins.attrs):
                    sub = self.cost_of(target)
                    out.flops += sub.flops
                    out.collective_bytes += sub.collective_bytes
                continue
            if ins.op == "conditional":
                out.bytes_accessed += self._io_bytes(ins, table)
                branches = _COND_BRANCHES_RE.search(ins.attrs)
                names = (_OPERAND_NAME.findall(branches.group(1))
                         if branches else _CALLS_RE.findall(ins.attrs))
                subs = [self.cost_of(n) for n in names]
                if subs:
                    worst = max(subs, key=lambda c: c.flops)
                    out.add(worst)
                continue
            if ins.op in _FREE_OPS:
                continue
            io = self._io_bytes(ins, table)
            out.bytes_accessed += io
            if ins.op in _TRAFFIC_OPS or ins.op in COLLECTIVE_MULTIPLIER:
                out.bytes_fused += io
            if ins.op in ("dot", "dot-general"):
                out.flops += self._dot_flops(ins, table)
            if ins.op in COLLECTIVE_MULTIPLIER:
                buf = _type_bytes(ins.type_str)
                if ins.op.startswith(("all-reduce", "reduce-scatter",
                                      "all-to-all", "collective-permute")):
                    # use operand bytes for reduce-style ops
                    op_bytes = sum(_type_bytes(table.get(o, ""))
                                   for o in ins.operands)
                    buf = max(buf, op_bytes)
                out.collective_bytes += COLLECTIVE_MULTIPLIER[ins.op] * buf
                out.collective_counts[ins.op] = \
                    out.collective_counts.get(ins.op, 0) + 1
        return out

    def _fusion_is_elementwise(self, ins: _Instr) -> bool:
        """True if every op in the fusion body is pure elementwise (would be
        fused away on TPU)."""
        for target in _CALLS_RE.findall(ins.attrs):
            if target in self._ew_memo:
                return self._ew_memo[target]
            comp = self.comps.get(target)
            ok = comp is not None and all(
                i.op in _EW_OPS for i in comp.instrs)
            self._ew_memo[target] = ok
            return ok
        return False

    def _io_bytes(self, ins: _Instr, table: dict[str, str]) -> float:
        total = float(_type_bytes(ins.type_str))
        for o in ins.operands:
            t = table.get(o)
            if t is not None:
                total += _type_bytes(t)
        return total

    def entry_cost(self) -> HloCost:
        if self._entry is None:
            return HloCost(warnings=["no ENTRY computation found"])
        return self.cost_of(self._entry)


def analyze_hlo_text(text: str) -> HloCost:
    return HloAnalyzer(text).entry_cost()
