"""Training driver.

Runs real optimization steps on the local device(s):

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
      --steps 20 --batch 4 --seq 128

``--smoke`` swaps in the reduced same-family config (the full configs are
for the dry-run / real pods). With a mesh larger than one device the step is
jit-compiled with the same sharding rules the dry-run proves out; on one CPU
device it runs unsharded. Checkpoints land in --ckpt-dir every
--ckpt-every steps and training resumes from the latest checkpoint
automatically (crash-restart story).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config, smoke_variant
from repro.configs.base import TrainConfig
from repro.data import TokenPipeline
from repro.models import model as M
from repro.optim import TrainState, cosine_schedule, make_optimizer


def build(arch: str, smoke: bool, train_cfg: TrainConfig):
    cfg = get_config(arch)
    if smoke:
        cfg = smoke_variant(cfg)
    opt = make_optimizer(
        train_cfg.optimizer,
        cosine_schedule(train_cfg.learning_rate, train_cfg.warmup_steps,
                        train_cfg.total_steps),
        weight_decay=train_cfg.weight_decay, grad_clip=train_cfg.grad_clip)
    return cfg, opt


def make_batch_fn(cfg, batch, seq, seed=0):
    pipe = TokenPipeline(cfg.vocab_size, seq, batch, seed=seed)

    def get(step: int) -> dict:
        b = pipe.batch(step)
        out = {"tokens": b["tokens"], "labels": b["labels"]}
        if cfg.family == "encdec":
            rng = np.random.default_rng(1000 + step)
            out["frames"] = rng.standard_normal(
                (batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
        if cfg.mrope:
            out["positions"] = np.broadcast_to(
                np.arange(seq, dtype=np.int32)[None, None], (3, batch, seq))
            out["vision_embeds"] = np.zeros(
                (batch, cfg.vision_tokens, cfg.d_model), np.float32)
        return out

    return get


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="yi-9b", choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "adafactor", "sgd"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args()

    tc = TrainConfig(learning_rate=args.lr, warmup_steps=10,
                     total_steps=args.steps, optimizer=args.optimizer,
                     grad_accum=args.grad_accum, remat_policy="none")
    cfg, opt = build(args.arch, args.smoke, tc)
    step_fn = jax.jit(M.make_train_step(cfg, opt, tc))
    state = M.init_train_state(cfg, opt, jax.random.PRNGKey(args.seed))

    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        if mgr.latest_step() is not None:
            restored, meta = mgr.restore(state)
            state = restored
            print(f"resumed from step {meta['step']}")

    get_batch = make_batch_fn(cfg, args.batch, args.seq, args.seed)
    start = int(state.step)
    t0 = time.time()
    for s in range(start, args.steps):
        state, metrics = step_fn(state, get_batch(s))
        if s % args.log_every == 0 or s == args.steps - 1:
            print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"({(time.time()-t0)/(s-start+1):.2f}s/step)", flush=True)
        if mgr and (s + 1) % args.ckpt_every == 0:
            mgr.save(s + 1, state, {"arch": args.arch})
    if mgr:
        mgr.save(args.steps, state, {"arch": args.arch})
    print("done")


if __name__ == "__main__":
    main()
