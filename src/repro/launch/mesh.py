"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init, and tests/benches must keep seeing 1 device.

Topology: TPU v5e pods of 256 chips as a (data=16, model=16) mesh; the
multi-pod mesh adds a leading "pod" axis — in this framework the pod axis IS
the federated-learning client axis (DESIGN.md §2.3): gradients all-reduce
over (pod, data) during joint training, and the FL aggregation step pmean's
parameters over "pod" exactly as the paper's Eq. (1) server does over the
simulated WAN.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU-subprocess sharding tests."""
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
