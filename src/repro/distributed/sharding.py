"""Logical-axis sharding: one table maps logical tensor axes to mesh axes.

Model code annotates tensors with *logical* axes ("batch", "heads", ...);
the active rule set (chosen per arch x shape x perf-iteration) resolves them
to mesh axes. Outside a mesh context everything is a no-op, so smoke tests on
one CPU device run the exact same model code.

Rule presets:
 * TRAIN_RULES     — FSDP(data) x TP(model); batch over (pod, data).
 * DECODE_RULES    — batch over (pod, data), heads over model, KV seq local.
 * LONG_DECODE_RULES — batch=1: KV sequence sharded over data (GSPMD inserts
   the online-softmax combine collectives); heads over model.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# logical axis -> mesh axis (or tuple of mesh axes, or None = replicated)
TRAIN_RULES: dict[str, object] = {
    "batch": ("pod", "data"),
    "act_seq": None,
    "heads": "model",
    "kv_heads": None,
    "head_dim": None,
    "d_ff": "model",
    "experts": "model",
    "vocab": "model",
    "embed_d": "data",        # FSDP axis of the embedding table
    "w_data": "data",         # FSDP axis of weight matrices
    "layers": None,
    "kv_seq": None,
    "state": None,
}

DECODE_RULES = dict(TRAIN_RULES, **{
    "w_data": None,           # weights replicated across data at serve time
    "embed_d": None,
    "batch": ("pod", "data"),
    "kv_seq": "model",        # KV cache sequence sharded over TP (GSPMD
                              # inserts the online-softmax combine)
})

LONG_DECODE_RULES = dict(DECODE_RULES, **{
    "batch": None,            # global_batch=1 cannot shard
    "kv_seq": ("pod", "data", "model"),  # 500k KV over every available axis
})


def rules_for(cfg, shape, mesh, *, base: dict | None = None) -> dict:
    """Resolve the rule preset for (arch, shape) on a given mesh, dropping
    any logical->mesh mapping whose dimension does not divide evenly (e.g.
    36 or 25 heads on a 16-way model axis fall back to replication; the MLP
    d_ff TP still applies). This is what makes all 10 archs lowerable on the
    production mesh without per-arch hand-tuning."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = sizes.get("model", 1)
    dp = sizes.get("data", 1)
    pod = sizes.get("pod", 1)
    if base is None:
        if shape.mode == "train":
            base = TRAIN_RULES
        elif shape.name == "long_500k":
            base = LONG_DECODE_RULES
        else:
            base = DECODE_RULES

    rules = dict(base)
    hd = cfg.resolved_head_dim

    def drop_if(axis: str, dim: int, ways: int):
        if rules.get(axis) is not None and dim % ways != 0:
            rules[axis] = None

    drop_if("heads", cfg.num_heads, tp)
    drop_if("kv_heads", cfg.num_kv_heads, tp)
    if cfg.d_ff:
        drop_if("d_ff", cfg.d_ff, tp)
    drop_if("vocab", cfg.padded_vocab, tp)
    drop_if("d_inner", cfg.d_model, tp)          # hybrid SSM inner == d
    fsdp_ways = dp
    drop_if("w_data", cfg.d_model, fsdp_ways)
    drop_if("embed_d", cfg.d_model, fsdp_ways)
    # batch: try (pod,data); fall back to data-only; then replicate
    b = shape.global_batch
    if rules.get("batch") is not None:
        if b % (pod * dp) == 0:
            rules["batch"] = tuple(a for a in ("pod", "data")
                                   if a in sizes) or None
        elif b % dp == 0:
            rules["batch"] = "data"
        else:
            rules["batch"] = None
    if rules.get("kv_seq") is not None and shape.mode in ("decode",
                                                          "prefill"):
        target = rules["kv_seq"]
        names = target if isinstance(target, tuple) else (target,)
        ways = 1
        for nm in names:
            ways *= sizes.get(nm, 1)
        kv_len = shape.kv_len or shape.seq_len
        if kv_len % ways != 0:
            rules["kv_seq"] = None
    return rules

_STATE = threading.local()


def _get() -> tuple[Optional[Mesh], dict]:
    return (getattr(_STATE, "mesh", None), getattr(_STATE, "rules",
                                                   TRAIN_RULES))


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate (mesh, rules) for logical_spec/constraint inside this block."""
    prev = _get()
    _STATE.mesh = mesh
    _STATE.rules = rules if rules is not None else TRAIN_RULES
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev


def active_mesh() -> Optional[Mesh]:
    return _get()[0]


def logical_spec(*logical_axes: Optional[str]) -> P:
    """Resolve logical axis names to a PartitionSpec under the active rules,
    dropping mesh axes the active mesh does not have."""
    mesh, rules = _get()
    names = set(mesh.axis_names) if mesh is not None else set()
    out = []
    for ax in logical_axes:
        if ax is None:
            out.append(None)
            continue
        target = rules.get(ax)
        if target is None:
            out.append(None)
        elif isinstance(target, tuple):
            kept = tuple(t for t in target if t in names)
            out.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        else:
            out.append(target if target in names else None)
    return P(*out)


def constraint(x, *logical_axes: Optional[str]):
    """with_sharding_constraint under the active mesh; identity otherwise."""
    mesh, _ = _get()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, logical_spec(*logical_axes)))


def named_sharding(*logical_axes: Optional[str]) -> Optional[NamedSharding]:
    mesh, _ = _get()
    if mesh is None:
        return None
    return NamedSharding(mesh, logical_spec(*logical_axes))


def _is_spec_leaf(x) -> bool:
    """A logical-axes tuple: a *plain* tuple of axis names / None. NamedTuples
    (e.g. TrainState) are containers, not leaves."""
    return (type(x) is tuple
            and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(spec_tree):
    """Map a pytree of logical-axis tuples to NamedShardings (active mesh)."""
    mesh, _ = _get()
    if mesh is None:
        raise RuntimeError("tree_shardings requires an active use_mesh()")
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, logical_spec(*axes)),
        spec_tree, is_leaf=_is_spec_leaf)
