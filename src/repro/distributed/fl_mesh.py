"""In-fabric federated aggregation over the mesh's ``pod`` axis.

This is the production mapping of the paper's transport (DESIGN.md §2.3):
each pod is one FL client; its model copy is the leading ``pod`` dimension of
a stacked parameter tree. One FL round's aggregation = paper Eq. (1)/FedAvg
across that axis:

 * ``exact``  — mean over the pod axis (GSPMD lowers to a bf16 all-reduce:
   the cross-pod DCI carries 2 x 2 bytes/param).
 * ``int8``   — the beyond-paper compressed exchange: each pod blockwise
   absmax-int8 quantizes its copy (the SAME codec as the MUDP wire /
   quantize kernel), all-gathers the int8 payloads + scales across pods,
   dequantizes and averages locally. Cross-pod bytes drop ~4x; quantization
   error is bounded by absmax/254 per block (tested) and an error-feedback
   residual can absorb it across rounds.

Both variants lower + compile on the (pod, data, model) production mesh —
the §Perf log records the collective-byte delta for granite-34b.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

QBLOCK = 1024


def client_mesh(devices=None) -> "jax.sharding.Mesh":
    """A 1-D ``("clients",)`` mesh over the local devices.

    The fleet's ``shard`` train backend
    (:class:`repro.core.client_compute.ShardBackend`) splits each vmapped
    training batch over this axis, one contiguous slab of clients per
    device; with a single device the backend skips the mesh entirely and
    runs plain vmap, so this helper is only consulted when there is
    something to shard over.
    """
    import numpy as np
    if devices is None:
        devices = jax.devices()
    return jax.sharding.Mesh(np.asarray(devices), ("clients",))


def stack_for_pods(params: Any, n_pods: int) -> Any:
    """Replicate a template tree into per-pod copies (leading pod dim)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_pods,) + x.shape), params)


def stacked_specs(param_specs: Any) -> Any:
    from repro.distributed.sharding import _is_spec_leaf
    return jax.tree_util.tree_map(lambda s: ("fl_pod",) + s, param_specs,
                                  is_leaf=_is_spec_leaf)


def _quantize_leaf(x: jax.Array):
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    nb = -(-n // QBLOCK)
    pad = nb * QBLOCK - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    blocks = flat.reshape(nb, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.rint(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array, shape, dtype):
    out = (q.astype(jnp.float32) * scale[..., None]).reshape(q.shape[0], -1)
    n = 1
    for s in shape:
        n *= s
    return out[..., :n].reshape((q.shape[0],) + tuple(shape)).astype(dtype)


def make_fl_aggregate(mesh, *, mode: str = "exact"):
    """Returns agg(stacked_params) -> stacked_params with every pod holding
    the aggregate (paper Eq. 1 semantics generalized to N pods)."""
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)

    if mode == "exact":
        def agg(stacked):
            return jax.tree_util.tree_map(
                lambda x: jnp.broadcast_to(
                    jnp.mean(x.astype(jnp.float32), axis=0,
                             keepdims=True).astype(x.dtype), x.shape),
                stacked)
        return agg

    if mode != "int8":
        raise ValueError(mode)

    def agg(stacked):
        def leaf(x):
            # x: (pod, ...) sharded pod on dim0. Quantization is ROW-wise
            # (absmax over the last dim) so it composes with the 2D
            # data/model sharding of the other dims — a flattened 1024-block
            # layout would force a full-parameter gather (measured: 185x
            # worse; §Perf log).
            def local(x_l):
                xe = x_l[0].astype(jnp.float32)
                scale = jnp.maximum(jnp.max(jnp.abs(xe), axis=-1), 1e-12) \
                    / 127.0
                q = jnp.clip(jnp.rint(xe / scale[..., None]), -127,
                             127).astype(jnp.int8)
                q_all = jax.lax.all_gather(q, "pod")         # (P, ...)
                s_all = jax.lax.all_gather(scale, "pod")
                deq = q_all.astype(jnp.float32) * s_all[..., None]
                return jnp.mean(deq, axis=0)[None].astype(x_l.dtype)

            in_spec = P(*(("pod",) + (None,) * (x.ndim - 1)))
            return jax.shard_map(local, mesh=mesh, in_specs=in_spec,
                                 out_specs=in_spec, check_vma=False,
                                 axis_names={"pod"})(x)
        return jax.tree_util.tree_map(leaf, stacked)

    return agg
